//! Experiment configuration system.
//!
//! Configs are TOML-subset files (sections, `key = value`, strings, ints,
//! floats, bools, inline arrays — parsed by [`toml::TomlDoc`], no external
//! deps) plus `--key value` CLI overrides. [`ExperimentConfig`] is the
//! validated, typed result consumed by [`crate::coordinator::Trainer`].

pub mod toml;

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Which training algorithm runs (see `rust/src/algorithms/`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Algorithm 1 of the paper: global mask + server-side momentum.
    RoSdhb,
    /// §3.3: per-worker independent masks.
    RoSdhbLocal,
    /// Appendix B baseline (GD variant, p = 1).
    ByzDashaPage,
    /// SOTA-no-compression baseline [3]: robust DGD (+ optional momentum).
    RobustDgd,
    /// Appendix C: RoSDHB-Local generalized to any unbiased compressor
    /// (see the `compressor` key).
    RoSdhbU,
    /// SOTA-no-robustness baseline [1]: DGD + RandK, plain averaging.
    DgdRandK,
    /// Plain distributed GD (no compression, no robustness).
    Dgd,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rosdhb" => Algorithm::RoSdhb,
            "rosdhb-local" | "rosdhb_local" => Algorithm::RoSdhbLocal,
            "rosdhb-u" | "rosdhb_u" => Algorithm::RoSdhbU,
            "byz-dasha-page" | "dasha" => Algorithm::ByzDashaPage,
            "robust-dgd" | "robustdgd" => Algorithm::RobustDgd,
            "dgd-randk" | "dgdrandk" => Algorithm::DgdRandK,
            "dgd" => Algorithm::Dgd,
            other => return Err(format!("unknown algorithm '{other}'")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::RoSdhb => "rosdhb",
            Algorithm::RoSdhbLocal => "rosdhb-local",
            Algorithm::RoSdhbU => "rosdhb-u",
            Algorithm::ByzDashaPage => "byz-dasha-page",
            Algorithm::RobustDgd => "robust-dgd",
            Algorithm::DgdRandK => "dgd-randk",
            Algorithm::Dgd => "dgd",
        }
    }
}

/// Gradient execution engine for honest workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure-Rust model (`rust/src/model`) — parallel sweeps; numerics
    /// cross-checked against the artifacts in `rust/tests/`.
    Native,
    /// AOT artifacts via PJRT (`rust/src/runtime`) — the three-layer path.
    Pjrt,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Self, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "native" => Engine::Native,
            "pjrt" => Engine::Pjrt,
            other => return Err(format!("unknown engine '{other}'")),
        })
    }
}

/// Dataset selection.
#[derive(Clone, Debug, PartialEq)]
pub enum Dataset {
    /// Deterministic MNIST-like synthetic task (DESIGN.md §1).
    Synthetic,
    /// Real MNIST from IDX files in the given directory.
    MnistIdx(String),
}

/// Fully-resolved experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub algorithm: Algorithm,
    pub engine: Engine,
    pub dataset: Dataset,
    /// Honest worker count (paper: 10).
    pub n_honest: usize,
    /// Byzantine worker count f (paper: 1,3,5,7,9).
    pub n_byz: usize,
    /// Aggregator spec, e.g. "cwtm", "nnm+cwtm", "geomed", "krum", "mean".
    pub aggregator: String,
    /// Attack spec, e.g. "alie", "ipm", "signflip", "labelflip", "noise",
    /// "mimic", "none".
    pub attack: String,
    /// Compression ratio k/d in (0, 1]; 1.0 = no sparsification.
    pub k_frac: f64,
    /// Unbiased compressor for `rosdhb-u` (Appendix C): "randk",
    /// "qsgd" or "qsgd:<levels>".
    pub compressor: String,
    /// Data partition across honest workers: "iid" (paper's setup) or
    /// "dirichlet:<alpha>" (label-skew non-iid; small alpha ⇒ large (G,B)).
    pub partition: String,
    /// Momentum coefficient β ∈ [0, 1).
    pub beta: f32,
    /// Learning rate γ.
    pub gamma: f32,
    /// Multiplicative per-round decay of γ (1.0 = constant; e.g. 0.999).
    pub gamma_decay: f32,
    /// Clip ‖R^t‖ to this value before stepping (0 = no clipping).
    pub clip: f32,
    /// Total rounds T.
    pub rounds: usize,
    /// Mini-batch size per worker per round (paper: 60). 0 = full batch.
    pub batch: usize,
    /// Target test accuracy τ (paper: 0.85); reaching it is recorded but
    /// does not stop training unless `stop_at_tau`.
    pub tau: f64,
    pub stop_at_tau: bool,
    /// Evaluate test accuracy every this many rounds.
    pub eval_every: usize,
    /// Root seed; all randomness derives from it.
    pub seed: u64,
    /// Artifacts directory for the PJRT engine.
    pub artifacts_dir: String,
    /// Optional CSV output path for per-round metrics.
    pub csv_out: Option<String>,
    /// Record Lyapunov diagnostics (δᵗ, Υᵗ) every eval (costs one extra
    /// full-gradient pass per honest worker).
    pub lyapunov: bool,
    /// Train-set size cap (synthetic: 60_000 like MNIST; tests use less).
    pub train_size: usize,
    pub test_size: usize,
    /// Size of the persistent gradient worker pool (native engine only).
    /// 0 = auto: min(worker count, available parallelism). Results are
    /// invariant to this value (each worker owns its RNG stream).
    pub pool_size: usize,
    /// Round-engine arithmetic path: "auto" | "dense" | "sparse" — see
    /// [`crate::algorithms::RoundMode`]. "dense" is the oracle path the
    /// sparse engine is tested against.
    pub round_engine: String,
    /// Exact-refresh period of the incrementally maintained pairwise
    /// geometry used by Krum/Multi-Krum/NNM under the sparse engine:
    /// `"never"`, or an integer p ≥ 1 — the n×n distance matrix is
    /// rebuilt from the raw momenta every p-th round (p = 1 disables
    /// incremental updates entirely and is bit-identical to the dense
    /// oracle). See [`crate::aggregators::geometry::RefreshPeriod`].
    pub geometry_refresh: String,
    /// Round-exchange transport: "local" (in-process worker pool — the
    /// tested oracle) or "tcp" (socket-backed coordinator/worker split;
    /// run the coordinator with `rosdhb serve` and each worker with
    /// `rosdhb join`). RunReports are bit-identical between the two on
    /// the same config/seed.
    pub transport: String,
    /// Bind address of the coordinator under `transport = "tcp"`
    /// (port 0 = ephemeral).
    pub listen_addr: String,
    /// Address workers dial under `transport = "tcp"` (`rosdhb join`).
    pub coordinator_addr: String,
    /// Per-round uplink deadline in milliseconds under `transport =
    /// "tcp"`: a worker that misses it has its contribution dropped
    /// (zero gradient) and is evicted from later rounds instead of
    /// stalling the run.
    pub round_timeout_ms: u64,
    /// Downlink encoding: "dense" (full model every round — the
    /// pre-subsystem behavior) or "delta" (workers keep a model replica
    /// and receive the previous aggregate, delta-coded to the k masked
    /// values whenever the off-mask carry law held bit-exactly; dense
    /// fallback otherwise — bit-identical results either way). See
    /// [`crate::transport::downlink`].
    pub downlink: String,
    /// Broadcast fan-out: "flat" (one coordinator write per worker) or
    /// "tree" (workers re-forward frames to `branching` children each;
    /// coordinator egress drops from n·B to branching·B per round).
    pub fanout: String,
    /// Relay-tree branching factor (`fanout = "tree"`; ignored under
    /// flat).
    pub branching: usize,
    /// Uplink reduction mode: "forward" (every worker's contribution
    /// travels end-to-end to the coordinator — the value-forwarding
    /// default, required by robust rules and payload attacks) or
    /// "aggregate" (interior relays fold their subtree's contributions
    /// into one accumulated `AGG` frame; coordinator ingress drops from
    /// n·B to branching·B). Only sum/mean-shaped rules qualify
    /// (`dgd`, `robust-dgd`, `byz-dasha-page` under `aggregator =
    /// "mean"`). Fingerprinted: the mode pins the f32 summation order
    /// (see [`crate::transport::uplink`]), so both sides must agree.
    pub uplink: String,
    /// Socket runtime under `transport = "tcp"`: "threads" (one blocking
    /// reader/writer thread pair per connection — the bit-parity oracle)
    /// or "evloop" (a single readiness-polling I/O thread per process
    /// driving every socket nonblocking; scales past the thread budget
    /// and feeds the connection monitor that steers relay placement and
    /// stalled-relay resyncs). Both runtimes speak the identical wire
    /// format; under `fanout = "flat"` they interoperate freely, under
    /// `fanout = "tree"` all sides must pick the same mode (the relay
    /// feeds differ). Deliberately NOT part of the wire fingerprint:
    /// results are bit-identical across modes.
    pub io: String,
    /// Rounds per epoch (0 = no epochs — the pre-elastic behavior).
    /// With `epoch_rounds = E`, round `t` belongs to epoch `(t-1)/E`; at
    /// every epoch boundary the membership may change (leaves, joins,
    /// readmissions), workers are rebuilt from `(seed, epoch, shard)`
    /// alone, and the coordinator may write a checkpoint.
    pub epoch_rounds: usize,
    /// What happens to a worker suspended for a missed round deadline:
    /// "never" (suspended for the rest of the run — the old eviction) or
    /// "next-epoch" (re-admitted at the next epoch boundary if its
    /// connection is still healthy).
    pub readmit: String,
    /// Membership churn schedule, coordinator-local (never fingerprinted):
    /// comma-separated `<epoch>:[+-]<slot>` events. `-` vacates the slot
    /// starting at that epoch's boundary; `+` re-fills it from the
    /// re-opened rendezvous. Example: `"1:-2,2:+2"` — slot 2 leaves at
    /// the end of epoch 0 and a replacement joins one epoch later.
    pub churn: String,
    /// Telemetry journal path ("" = tracing off — every emit site
    /// reduces to one branch on a disabled handle). The coordinator
    /// writes `<trace_path>` as JSONL; each `join` process writes
    /// `<trace_path>.w<id>`. Process-local observability only — never
    /// fingerprinted, never on the wire, and provably inert: runs are
    /// bit-identical with tracing on or off (`tests/test_telemetry.rs`).
    pub trace_path: String,
    /// Live status endpoint bind address ("" = off). `serve
    /// --status_addr 127.0.0.1:7900` answers every TCP connection with
    /// one JSON run snapshot (see `docs/OBSERVABILITY.md`).
    /// Coordinator-local and read-only — never fingerprinted.
    pub status_addr: String,
    /// Aggregation forensics: when `true` the coordinator arms a
    /// per-round collector around the aggregation call and folds what
    /// the rules observed (Krum scores/selection, NNM neighbor sets,
    /// CWTM trim inclusion, Weiszfeld residuals, pairwise distances)
    /// into per-worker rolling suspicion statistics, journaled as
    /// `agg_forensics` / `suspicion_snapshot` events and surfaced in
    /// the status snapshot and RunReport. Strictly an observer: never
    /// fingerprinted, never on the wire, results bit-identical on/off.
    pub forensics: bool,
    /// Depth of the status endpoint's in-memory round-history ring
    /// served at `GET /history` (0 = no history). Coordinator-local —
    /// never fingerprinted.
    pub status_history: usize,
}

/// One membership-churn event (see [`ExperimentConfig::churn`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    /// The epoch whose *opening* boundary applies the event (epoch e
    /// starts at round `e * epoch_rounds + 1`).
    pub epoch: u64,
    /// Gradient slot the event applies to.
    pub slot: usize,
    /// `true` = the slot is (re-)filled at this boundary, `false` = the
    /// worker occupying it leaves.
    pub join: bool,
}

/// Parse a churn schedule: `""` ⇒ no events, else comma-separated
/// `<epoch>:[+-]<slot>` triples sorted by (epoch, slot).
pub fn parse_churn(spec: &str) -> Result<Vec<ChurnEvent>, String> {
    let mut events = Vec::new();
    for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (epoch_s, rest) = item
            .split_once(':')
            .ok_or_else(|| format!("churn '{item}': want <epoch>:[+-]<slot>"))?;
        let epoch: u64 = epoch_s
            .trim()
            .parse()
            .map_err(|_| format!("churn '{item}': bad epoch '{epoch_s}'"))?;
        if epoch == 0 {
            return Err(format!(
                "churn '{item}': epoch 0 has no opening boundary — initial \
                 membership comes from rendezvous"
            ));
        }
        let rest = rest.trim();
        let (join, slot_s) = match rest.as_bytes().first() {
            Some(b'+') => (true, &rest[1..]),
            Some(b'-') => (false, &rest[1..]),
            _ => {
                return Err(format!(
                    "churn '{item}': slot must be prefixed with + (join) \
                     or - (leave)"
                ))
            }
        };
        let slot: usize = slot_s
            .parse()
            .map_err(|_| format!("churn '{item}': bad slot '{slot_s}'"))?;
        events.push(ChurnEvent { epoch, slot, join });
    }
    events.sort_by_key(|e| (e.epoch, e.slot, e.join));
    Ok(events)
}

impl ExperimentConfig {
    /// Paper's Figure-1 setup with defaults: 10 honest workers, ALIE,
    /// CWTM, β=0.9, B=60, τ=0.85.
    pub fn default_mnist_like() -> Self {
        ExperimentConfig {
            algorithm: Algorithm::RoSdhb,
            engine: Engine::Native,
            dataset: Dataset::Synthetic,
            n_honest: 10,
            n_byz: 3,
            aggregator: "nnm+cwtm".into(),
            attack: "alie".into(),
            k_frac: 0.1,
            compressor: "qsgd:4".into(),
            partition: "iid".into(),
            beta: 0.9,
            gamma: 0.05,
            gamma_decay: 1.0,
            clip: 0.0,
            rounds: 5000,
            batch: 60,
            tau: 0.85,
            stop_at_tau: true,
            eval_every: 10,
            seed: 1,
            artifacts_dir: "artifacts".into(),
            csv_out: None,
            lyapunov: false,
            train_size: 60_000,
            test_size: 10_000,
            pool_size: 0,
            round_engine: "auto".into(),
            geometry_refresh: "64".into(),
            transport: "local".into(),
            listen_addr: "127.0.0.1:7177".into(),
            coordinator_addr: "127.0.0.1:7177".into(),
            round_timeout_ms: 30_000,
            downlink: "dense".into(),
            fanout: "flat".into(),
            branching: 2,
            uplink: "forward".into(),
            io: "threads".into(),
            epoch_rounds: 0,
            readmit: "next-epoch".into(),
            churn: String::new(),
            trace_path: String::new(),
            status_addr: String::new(),
            forensics: false,
            status_history: crate::telemetry::status::DEFAULT_HISTORY_DEPTH,
        }
    }

    /// Total workers n = |H| + f.
    pub fn n_total(&self) -> usize {
        self.n_honest + self.n_byz
    }

    /// Build from a parsed TOML document (all keys optional, defaults from
    /// [`Self::default_mnist_like`]). Keys live at top level or under
    /// `[experiment]`.
    pub fn from_toml(doc: &toml::TomlDoc) -> Result<Self, String> {
        let c = Self::from_toml_unchecked(doc)?;
        c.validate()?;
        Ok(c)
    }

    /// [`Self::from_toml`] without the final [`Self::validate`] pass —
    /// used by [`Self::set`], where a single key may only be valid in
    /// combination with the base it merges into (e.g. `uplink =
    /// "aggregate"` after `algorithm = "dgd"`); only the merged config
    /// is validated there.
    fn from_toml_unchecked(doc: &toml::TomlDoc) -> Result<Self, String> {
        let mut c = Self::default_mnist_like();
        let get = |k: &str| {
            doc.get("experiment", k).or_else(|| doc.get("", k))
        };
        if let Some(v) = get("algorithm") {
            c.algorithm = Algorithm::parse(v.as_str().ok_or("algorithm: want string")?)?;
        }
        if let Some(v) = get("engine") {
            c.engine = Engine::parse(v.as_str().ok_or("engine: want string")?)?;
        }
        if let Some(v) = get("dataset") {
            let s = v.as_str().ok_or("dataset: want string")?;
            c.dataset = if s == "synthetic" {
                Dataset::Synthetic
            } else {
                Dataset::MnistIdx(s.to_string())
            };
        }
        macro_rules! num {
            ($key:expr, $field:expr, $ty:ty) => {
                if let Some(v) = get($key) {
                    $field = v
                        .as_f64()
                        .ok_or(concat!($key, ": want number"))? as $ty;
                }
            };
        }
        num!("n_honest", c.n_honest, usize);
        num!("n_byz", c.n_byz, usize);
        num!("k_frac", c.k_frac, f64);
        num!("beta", c.beta, f32);
        num!("gamma", c.gamma, f32);
        num!("gamma_decay", c.gamma_decay, f32);
        num!("clip", c.clip, f32);
        num!("rounds", c.rounds, usize);
        num!("batch", c.batch, usize);
        num!("tau", c.tau, f64);
        num!("eval_every", c.eval_every, usize);
        num!("seed", c.seed, u64);
        num!("train_size", c.train_size, usize);
        num!("test_size", c.test_size, usize);
        num!("pool_size", c.pool_size, usize);
        num!("round_timeout_ms", c.round_timeout_ms, u64);
        num!("branching", c.branching, usize);
        num!("epoch_rounds", c.epoch_rounds, usize);
        num!("status_history", c.status_history, usize);
        if let Some(v) = get("round_engine") {
            c.round_engine =
                v.as_str().ok_or("round_engine: want string")?.into();
        }
        if let Some(v) = get("geometry_refresh") {
            // accept both `geometry_refresh = 8` and `= "never"`
            c.geometry_refresh = match v.as_str() {
                Some(s) => s.into(),
                None => {
                    let x = v
                        .as_f64()
                        .ok_or("geometry_refresh: want int or \"never\"")?;
                    format!("{}", x as u64)
                }
            };
        }
        if let Some(v) = get("transport") {
            c.transport = v.as_str().ok_or("transport: want string")?.into();
        }
        if let Some(v) = get("downlink") {
            c.downlink = v.as_str().ok_or("downlink: want string")?.into();
        }
        if let Some(v) = get("fanout") {
            c.fanout = v.as_str().ok_or("fanout: want string")?.into();
        }
        if let Some(v) = get("uplink") {
            c.uplink = v.as_str().ok_or("uplink: want string")?.into();
        }
        if let Some(v) = get("io") {
            c.io = v.as_str().ok_or("io: want string")?.into();
        }
        if let Some(v) = get("readmit") {
            c.readmit = v.as_str().ok_or("readmit: want string")?.into();
        }
        if let Some(v) = get("churn") {
            c.churn = v.as_str().ok_or("churn: want string")?.into();
        }
        if let Some(v) = get("trace_path") {
            c.trace_path = v.as_str().ok_or("trace_path: want string")?.into();
        }
        if let Some(v) = get("status_addr") {
            c.status_addr =
                v.as_str().ok_or("status_addr: want string")?.into();
        }
        if let Some(v) = get("listen_addr") {
            c.listen_addr =
                v.as_str().ok_or("listen_addr: want string")?.into();
        }
        if let Some(v) = get("coordinator_addr") {
            c.coordinator_addr =
                v.as_str().ok_or("coordinator_addr: want string")?.into();
        }
        if let Some(v) = get("compressor") {
            c.compressor = v.as_str().ok_or("compressor: want string")?.into();
        }
        if let Some(v) = get("partition") {
            c.partition = v.as_str().ok_or("partition: want string")?.into();
        }
        if let Some(v) = get("aggregator") {
            c.aggregator = v.as_str().ok_or("aggregator: want string")?.into();
        }
        if let Some(v) = get("attack") {
            c.attack = v.as_str().ok_or("attack: want string")?.into();
        }
        if let Some(v) = get("artifacts_dir") {
            c.artifacts_dir =
                v.as_str().ok_or("artifacts_dir: want string")?.into();
        }
        if let Some(v) = get("csv_out") {
            c.csv_out = Some(v.as_str().ok_or("csv_out: want string")?.into());
        }
        if let Some(v) = get("stop_at_tau") {
            c.stop_at_tau = v.as_bool().ok_or("stop_at_tau: want bool")?;
        }
        if let Some(v) = get("lyapunov") {
            c.lyapunov = v.as_bool().ok_or("lyapunov: want bool")?;
        }
        if let Some(v) = get("forensics") {
            c.forensics = v.as_bool().ok_or("forensics: want bool")?;
        }
        Ok(c)
    }

    /// Apply a `--key value` CLI override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let doc = toml::TomlDoc::parse(&format!(
            "{key} = {}",
            toml::quote_if_needed(value)
        ))?;
        let mut merged = self.clone();
        // Re-run from_toml-style assignment for the single key by building
        // a one-key doc; simplest correct path.
        let updated = ExperimentConfig::from_toml_with_base(&doc, merged.clone())?;
        merged = updated;
        *self = merged;
        Ok(())
    }

    fn from_toml_with_base(
        doc: &toml::TomlDoc,
        base: ExperimentConfig,
    ) -> Result<Self, String> {
        // Same key handling as from_toml, but starting from `base`.
        let mut c = base;
        let tmp = ExperimentConfig::from_toml_unchecked(doc)?;
        // from_toml starts from defaults; copy over only keys present.
        for (sect, key) in doc.keys() {
            let _ = sect;
            match key.as_str() {
                "algorithm" => c.algorithm = tmp.algorithm,
                "engine" => c.engine = tmp.engine,
                "dataset" => c.dataset = tmp.dataset.clone(),
                "n_honest" => c.n_honest = tmp.n_honest,
                "n_byz" => c.n_byz = tmp.n_byz,
                "aggregator" => c.aggregator = tmp.aggregator.clone(),
                "compressor" => c.compressor = tmp.compressor.clone(),
                "partition" => c.partition = tmp.partition.clone(),
                "attack" => c.attack = tmp.attack.clone(),
                "k_frac" => c.k_frac = tmp.k_frac,
                "beta" => c.beta = tmp.beta,
                "gamma" => c.gamma = tmp.gamma,
                "gamma_decay" => c.gamma_decay = tmp.gamma_decay,
                "clip" => c.clip = tmp.clip,
                "rounds" => c.rounds = tmp.rounds,
                "batch" => c.batch = tmp.batch,
                "tau" => c.tau = tmp.tau,
                "stop_at_tau" => c.stop_at_tau = tmp.stop_at_tau,
                "eval_every" => c.eval_every = tmp.eval_every,
                "seed" => c.seed = tmp.seed,
                "artifacts_dir" => c.artifacts_dir = tmp.artifacts_dir.clone(),
                "csv_out" => c.csv_out = tmp.csv_out.clone(),
                "lyapunov" => c.lyapunov = tmp.lyapunov,
                "train_size" => c.train_size = tmp.train_size,
                "test_size" => c.test_size = tmp.test_size,
                "pool_size" => c.pool_size = tmp.pool_size,
                "round_engine" => c.round_engine = tmp.round_engine.clone(),
                "geometry_refresh" => {
                    c.geometry_refresh = tmp.geometry_refresh.clone()
                }
                "transport" => c.transport = tmp.transport.clone(),
                "listen_addr" => c.listen_addr = tmp.listen_addr.clone(),
                "coordinator_addr" => {
                    c.coordinator_addr = tmp.coordinator_addr.clone()
                }
                "round_timeout_ms" => c.round_timeout_ms = tmp.round_timeout_ms,
                "downlink" => c.downlink = tmp.downlink.clone(),
                "fanout" => c.fanout = tmp.fanout.clone(),
                "branching" => c.branching = tmp.branching,
                "uplink" => c.uplink = tmp.uplink.clone(),
                "io" => c.io = tmp.io.clone(),
                "epoch_rounds" => c.epoch_rounds = tmp.epoch_rounds,
                "readmit" => c.readmit = tmp.readmit.clone(),
                "churn" => c.churn = tmp.churn.clone(),
                "trace_path" => c.trace_path = tmp.trace_path.clone(),
                "status_addr" => c.status_addr = tmp.status_addr.clone(),
                "forensics" => c.forensics = tmp.forensics,
                "status_history" => c.status_history = tmp.status_history,
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        c.validate()?;
        Ok(c)
    }

    /// Invariants every run must satisfy (paper §2: f < n/2 etc.).
    pub fn validate(&self) -> Result<(), String> {
        if self.n_honest == 0 {
            return Err("n_honest must be > 0".into());
        }
        if self.n_byz * 2 >= self.n_total() && self.n_byz > 0 {
            return Err(format!(
                "f={} >= n/2={} — no aggregation rule can be robust (§2)",
                self.n_byz,
                self.n_total() as f64 / 2.0
            ));
        }
        if !(self.k_frac > 0.0 && self.k_frac <= 1.0) {
            return Err("k_frac must be in (0, 1]".into());
        }
        if !(0.0..1.0).contains(&(self.beta as f64)) {
            return Err("beta must be in [0, 1)".into());
        }
        if self.gamma <= 0.0 {
            return Err("gamma must be > 0".into());
        }
        if !(self.gamma_decay > 0.0 && self.gamma_decay <= 1.0) {
            return Err("gamma_decay must be in (0, 1]".into());
        }
        if self.clip < 0.0 {
            return Err("clip must be >= 0".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be > 0".into());
        }
        match parse_partition(&self.partition) {
            Ok(_) => {}
            Err(e) => return Err(e),
        }
        if self.algorithm == Algorithm::RoSdhbU {
            // fail early on a bad compressor spec (build would panic);
            // CompressorSpec also enforces the wire bounds (qsgd s fits
            // the u16 field of the QuantBlock layout)
            crate::compression::CompressorSpec::parse(
                &self.compressor,
                8,
                self.k_frac,
            )
            .map(|_| ())?;
        }
        if self.eval_every == 0 {
            return Err("eval_every must be > 0".into());
        }
        // single source of truth for the accepted values (algorithms::build
        // later unwraps the same parse)
        crate::algorithms::RoundMode::parse(&self.round_engine)?;
        crate::aggregators::geometry::RefreshPeriod::parse(
            &self.geometry_refresh,
        )?;
        // downlink/fanout parse everywhere (the local transport models
        // their byte accounting so tcp runs stay bit-comparable to it)
        crate::transport::downlink::DownlinkMode::parse(&self.downlink)?;
        crate::transport::downlink::FanoutPlan::parse(
            &self.fanout,
            self.branching,
        )?;
        match self.uplink.as_str() {
            "forward" => {}
            "aggregate" => {
                // Partial aggregation only exists for sum/mean-shaped
                // reductions: relays fold f32 sums, so the rule must be
                // a (scaled) sum of the contributions. Robust rules and
                // payload attacks need the individual values.
                match self.algorithm {
                    Algorithm::Dgd
                    | Algorithm::RobustDgd
                    | Algorithm::ByzDashaPage => {}
                    other => {
                        return Err(format!(
                            "uplink = \"aggregate\" needs a sum/mean-shaped \
                             rule (dgd | robust-dgd | byz-dasha-page), not \
                             '{}' — robust selection rules must see every \
                             worker's value",
                            other.name()
                        ))
                    }
                }
                if self.aggregator != "mean" {
                    return Err(format!(
                        "uplink = \"aggregate\" requires aggregator = \
                         \"mean\" (got '{}'): relays ship subtree sums, \
                         robust rules keep value-forwarding",
                        self.aggregator
                    ));
                }
                let attack = crate::attacks::parse_spec(&self.attack)?;
                if matches!(attack, crate::attacks::AttackKind::Payload(_)) {
                    return Err(format!(
                        "uplink = \"aggregate\" cannot run payload attack \
                         '{}': crafted values must be individually \
                         forwarded — use uplink = \"forward\"",
                        self.attack
                    ));
                }
                if self.branching < 2 {
                    return Err(
                        "uplink = \"aggregate\" needs branching >= 2: the \
                         logical reduce tree uses it even under fanout = \
                         \"flat\""
                            .into(),
                    );
                }
                if self.lyapunov {
                    return Err(
                        "lyapunov diagnostics need per-worker momenta; \
                         uplink = \"aggregate\" keeps only their sum"
                            .into(),
                    );
                }
                if !self.churn.is_empty() {
                    return Err(
                        "uplink = \"aggregate\" needs a fixed roster: \
                         mid-run joiners ship dense re-init summands that \
                         cannot fold into peers' sparse frames — drop the \
                         churn schedule or use uplink = \"forward\""
                            .into(),
                    );
                }
                if self.n_byz > 0
                    && matches!(attack, crate::attacks::AttackKind::None)
                {
                    return Err(format!(
                        "uplink = \"aggregate\" with attack = \"none\" \
                         cannot carry {} silent byzantine slots: every \
                         slot must contribute to the running sum or the \
                         reduce stalls — use attack = \"labelflip\" or \
                         n_byz = 0",
                        self.n_byz
                    ));
                }
            }
            other => {
                return Err(format!(
                    "unknown uplink '{other}' (forward|aggregate)"
                ))
            }
        }
        match self.readmit.as_str() {
            "never" | "next-epoch" => {}
            other => {
                return Err(format!(
                    "unknown readmit '{other}' (never | next-epoch)"
                ))
            }
        }
        // io selects the tcp socket runtime but parses everywhere so a
        // config destined for `transport = "tcp"` fails fast under local
        match self.io.as_str() {
            "threads" | "evloop" => {}
            other => {
                return Err(format!("unknown io mode '{other}' (threads|evloop)"))
            }
        }
        if self.epoch_rounds > 0 && self.algorithm == Algorithm::ByzDashaPage {
            return Err(
                "epoch_rounds > 0 is not supported for byz-dasha-page: its \
                 client-side gradient-estimate state cannot survive the \
                 epoch-boundary worker rebuild"
                    .into(),
            );
        }
        let churn = parse_churn(&self.churn)?;
        if !churn.is_empty() {
            if self.epoch_rounds == 0 {
                return Err(
                    "churn needs epoch boundaries — set epoch_rounds > 0".into()
                );
            }
            for ev in &churn {
                if ev.slot >= self.n_honest {
                    return Err(format!(
                        "churn slot {} is not an honest gradient slot \
                         (n_honest = {})",
                        ev.slot, self.n_honest
                    ));
                }
            }
        }
        match self.transport.as_str() {
            "local" => {}
            "tcp" => {
                // Every algorithm has a typed wire plan (the payload
                // codec, `compression::payload`): shared-mask sparse,
                // worker-drawn masks with a shipped MaskWire, QSGD
                // blocks, DASHA differences, or dense. What the socket
                // runtime cannot reproduce is the *omniscient payload
                // adversary* on plans where the server never sees dense
                // honest gradients — crafting needs the full-d honest
                // inputs, which only the shared-mask plan (payload-space
                // crafting) and the dense plans expose. Data-level
                // attacks (labelflip: Byzantine workers are real
                // processes) and crash faults (none) run everywhere.
                let attack = crate::attacks::parse_spec(&self.attack)?;
                if matches!(attack, crate::attacks::AttackKind::Payload(_)) {
                    match self.algorithm {
                        Algorithm::RoSdhb
                        | Algorithm::RobustDgd
                        | Algorithm::Dgd => {}
                        other => {
                            return Err(format!(
                                "transport = \"tcp\" cannot run payload \
                                 attack '{}' with '{}': the omniscient \
                                 adversary is crafted server-side from \
                                 dense honest gradients, which this wire \
                                 plan never ships — use attack = \
                                 \"none\"/\"labelflip\", or transport = \
                                 \"local\"",
                                self.attack,
                                other.name()
                            ))
                        }
                    }
                }
                if self.engine != Engine::Native {
                    return Err(
                        "transport = \"tcp\" requires engine = \"native\"".into()
                    );
                }
                if self.lyapunov {
                    return Err(
                        "lyapunov diagnostics need dense worker gradients; \
                         use transport = \"local\""
                            .into(),
                    );
                }
                if self.round_timeout_ms == 0 {
                    return Err("round_timeout_ms must be > 0".into());
                }
            }
            other => {
                return Err(format!("unknown transport '{other}' (local|tcp)"))
            }
        }
        Ok(())
    }

    /// 64-bit digest of every field both sides of a `transport = "tcp"`
    /// run must agree on for the worker's locally rebuilt state (shards,
    /// RNG streams, wire plan) to match the coordinator's. Exchanged in
    /// the JOIN handshake; a mismatch refuses the worker at rendezvous.
    pub fn wire_fingerprint(&self) -> u64 {
        // The dataset enters by *kind* only: hashing a local MNIST path
        // would refuse multi-host runs that keep the same files at
        // different locations. File contents are the operator's
        // responsibility (synthetic data is fully pinned by seed/sizes,
        // which are hashed).
        let dataset_kind = match &self.dataset {
            Dataset::Synthetic => "synthetic",
            Dataset::MnistIdx(_) => "mnist-idx",
        };
        let canon = format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}",
            self.algorithm.name(),
            self.n_honest,
            self.n_byz,
            self.seed,
            self.k_frac,
            self.batch,
            self.attack,
            self.aggregator,
            self.partition,
            self.train_size,
            self.test_size,
            dataset_kind,
            // the compressor selects the rosdhb-u wire plan (randk vs
            // qsgd:s), i.e. what the worker-side CompressorState puts on
            // the uplink — both sides must agree
            self.compressor,
            // the downlink subsystem changes what travels server→worker
            // (delta frames need a replica; the tree needs relay
            // listeners) and the replica steps with the coordinator's
            // exact γ/decay/clip — every side must run the same values
            self.downlink,
            self.fanout,
            self.branching,
            self.gamma,
            self.gamma_decay,
            self.clip,
            // the epoch layer changes when worker state is rebuilt and
            // when dense re-sync broadcasts happen — every side must
            // agree; the churn *schedule* stays coordinator-local (a
            // worker needs no foreknowledge of who leaves or joins), so
            // `churn` is deliberately NOT hashed. `io` is NOT hashed
            // either: both socket runtimes speak the identical wire
            // format and produce bit-identical results, so mixed-mode
            // flat runs are legal (trees additionally need matching io,
            // enforced at plan application, not at rendezvous).
            // `trace_path`/`status_addr`/`forensics`/`status_history`
            // are likewise NOT hashed: telemetry is process-local
            // observation — a traced or forensics-armed coordinator
            // must accept untraced workers and vice versa
            self.epoch_rounds,
            self.readmit,
            // the uplink mode pins the f32 summation order (tree fold vs
            // per-value forwarding) and what each worker puts on the
            // wire (AGG frames vs GRAD messages) — both sides must agree
            self.uplink,
        );
        // FNV-1a, 64-bit
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in canon.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// JSON summary embedded in reports.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("algorithm".into(), Json::Str(self.algorithm.name().into()));
        m.insert(
            "engine".into(),
            Json::Str(
                match self.engine {
                    Engine::Native => "native",
                    Engine::Pjrt => "pjrt",
                }
                .into(),
            ),
        );
        m.insert("n_honest".into(), Json::Num(self.n_honest as f64));
        m.insert("n_byz".into(), Json::Num(self.n_byz as f64));
        m.insert("aggregator".into(), Json::Str(self.aggregator.clone()));
        m.insert("attack".into(), Json::Str(self.attack.clone()));
        m.insert("k_frac".into(), Json::Num(self.k_frac));
        m.insert("beta".into(), Json::Num(self.beta as f64));
        m.insert("gamma".into(), Json::Num(self.gamma as f64));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("batch".into(), Json::Num(self.batch as f64));
        m.insert("tau".into(), Json::Num(self.tau));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("transport".into(), Json::Str(self.transport.clone()));
        m.insert("downlink".into(), Json::Str(self.downlink.clone()));
        m.insert("fanout".into(), Json::Str(self.fanout.clone()));
        m.insert("uplink".into(), Json::Str(self.uplink.clone()));
        m.insert("branching".into(), Json::Num(self.branching as f64));
        m.insert("io".into(), Json::Str(self.io.clone()));
        m.insert("epoch_rounds".into(), Json::Num(self.epoch_rounds as f64));
        m.insert("readmit".into(), Json::Str(self.readmit.clone()));
        Json::Obj(m)
    }
}

/// Parse a partition spec into `None` (iid) or `Some(alpha)` (Dirichlet).
pub fn parse_partition(spec: &str) -> Result<Option<f64>, String> {
    let spec = spec.to_ascii_lowercase();
    if spec == "iid" {
        return Ok(None);
    }
    if let Some(arg) = spec.strip_prefix("dirichlet:") {
        let a: f64 = arg
            .parse()
            .map_err(|_| format!("bad dirichlet alpha '{arg}'"))?;
        if a <= 0.0 {
            return Err("dirichlet alpha must be > 0".into());
        }
        return Ok(Some(a));
    }
    Err(format!("unknown partition '{spec}' (iid | dirichlet:<alpha>)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default_mnist_like().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let doc = toml::TomlDoc::parse(
            r#"
            [experiment]
            algorithm = "rosdhb-local"
            n_honest = 10
            n_byz = 9
            k_frac = 0.01
            beta = 0.9
            gamma = 0.1
            attack = "alie"
            aggregator = "nnm+cwtm"
            rounds = 5000
            stop_at_tau = true
            "#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.algorithm, Algorithm::RoSdhbLocal);
        assert_eq!(c.n_byz, 9);
        assert_eq!(c.k_frac, 0.01);
    }

    #[test]
    fn rejects_majority_byzantine() {
        let mut c = ExperimentConfig::default_mnist_like();
        c.n_honest = 5;
        c.n_byz = 5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_bad_kfrac_beta() {
        let mut c = ExperimentConfig::default_mnist_like();
        c.k_frac = 0.0;
        assert!(c.validate().is_err());
        c.k_frac = 0.5;
        c.beta = 1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn cli_override_roundtrip() {
        let mut c = ExperimentConfig::default_mnist_like();
        c.set("k_frac", "0.05").unwrap();
        assert_eq!(c.k_frac, 0.05);
        c.set("algorithm", "dasha").unwrap();
        assert_eq!(c.algorithm, Algorithm::ByzDashaPage);
        assert!(c.set("nonsense_key", "1").is_err());
    }

    #[test]
    fn round_engine_and_pool_size_parse_and_validate() {
        let mut c = ExperimentConfig::default_mnist_like();
        assert_eq!(c.round_engine, "auto");
        assert_eq!(c.pool_size, 0);
        c.set("round_engine", "dense").unwrap();
        assert_eq!(c.round_engine, "dense");
        c.set("round_engine", "sparse").unwrap();
        c.set("pool_size", "4").unwrap();
        assert_eq!(c.pool_size, 4);
        assert!(c.set("round_engine", "banana").is_err());

        // geometry_refresh: "never" or an integer period >= 1
        assert_eq!(c.geometry_refresh, "64");
        c.set("geometry_refresh", "never").unwrap();
        assert_eq!(c.geometry_refresh, "never");
        c.set("geometry_refresh", "8").unwrap();
        assert_eq!(c.geometry_refresh, "8");
        assert!(c.set("geometry_refresh", "0").is_err());
        assert!(c.set("geometry_refresh", "often").is_err());
        let doc = toml::TomlDoc::parse(
            "[experiment]\ngeometry_refresh = 8\n",
        )
        .unwrap();
        assert_eq!(
            ExperimentConfig::from_toml(&doc).unwrap().geometry_refresh,
            "8"
        );
        let doc = toml::TomlDoc::parse(
            "[experiment]\ngeometry_refresh = \"never\"\n",
        )
        .unwrap();
        assert_eq!(
            ExperimentConfig::from_toml(&doc).unwrap().geometry_refresh,
            "never"
        );

        let doc = toml::TomlDoc::parse(
            "[experiment]\nround_engine = \"dense\"\npool_size = 2\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.round_engine, "dense");
        assert_eq!(c.pool_size, 2);
    }

    #[test]
    fn transport_keys_parse_and_validate() {
        let mut c = ExperimentConfig::default_mnist_like();
        assert_eq!(c.transport, "local");
        c.set("transport", "tcp").unwrap();
        c.set("listen_addr", "127.0.0.1:0").unwrap();
        c.set("coordinator_addr", "10.0.0.5:7177").unwrap();
        c.set("round_timeout_ms", "5000").unwrap();
        assert_eq!(c.round_timeout_ms, 5000);
        assert!(c.set("transport", "carrier-pigeon").is_err());

        // every algorithm has a tcp wire plan now, but the omniscient
        // payload adversary stays limited to plans whose uplinks expose
        // dense honest inputs (shared-mask rosdhb + dense baselines).
        // The default attack is "alie" (a payload attack):
        let mut c = ExperimentConfig::default_mnist_like();
        c.transport = "tcp".into();
        c.algorithm = Algorithm::ByzDashaPage;
        assert!(c.validate().is_err());
        c.algorithm = Algorithm::RoSdhbLocal;
        assert!(c.validate().is_err());
        c.algorithm = Algorithm::RoSdhbU;
        assert!(c.validate().is_err());
        // crash-fault and data-level attacks run everywhere; the
        // omniscient payload adversary is rejected on every one of
        // these plans (their uplinks never expose dense honest inputs)
        for algo in [
            Algorithm::ByzDashaPage,
            Algorithm::RoSdhbLocal,
            Algorithm::RoSdhbU,
            Algorithm::DgdRandK,
        ] {
            c.algorithm = algo;
            c.attack = "none".into();
            c.validate().unwrap();
            c.attack = "labelflip".into();
            c.validate().unwrap();
            c.attack = "alie".into();
            assert!(c.validate().is_err(), "{algo:?} must reject alie");
        }
        c.algorithm = Algorithm::RoSdhb;
        c.validate().unwrap();
        c.lyapunov = true;
        assert!(c.validate().is_err());

        let doc = toml::TomlDoc::parse(
            "[experiment]\ntransport = \"tcp\"\nlisten_addr = \"0.0.0.0:9000\"\n\
             round_timeout_ms = 1500\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.transport, "tcp");
        assert_eq!(c.listen_addr, "0.0.0.0:9000");
        assert_eq!(c.round_timeout_ms, 1500);
    }

    #[test]
    fn downlink_and_fanout_keys_parse_and_validate() {
        let mut c = ExperimentConfig::default_mnist_like();
        assert_eq!(c.downlink, "dense");
        assert_eq!(c.fanout, "flat");
        assert_eq!(c.branching, 2);
        c.set("downlink", "delta").unwrap();
        c.set("fanout", "tree").unwrap();
        c.set("branching", "3").unwrap();
        assert_eq!(c.branching, 3);
        c.validate().unwrap();
        assert!(c.set("downlink", "gossip").is_err());
        assert!(c.set("fanout", "ring").is_err());
        // branching 0 is rejected under the tree (but ignored under flat)
        c.branching = 0;
        assert!(c.validate().is_err());
        c.fanout = "flat".into();
        c.validate().unwrap();

        let doc = toml::TomlDoc::parse(
            "[experiment]\ndownlink = \"delta\"\nfanout = \"tree\"\n\
             branching = 4\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.downlink, "delta");
        assert_eq!(c.fanout, "tree");
        assert_eq!(c.branching, 4);

        // the downlink subsystem is part of the wire identity: every
        // side must agree on frames, topology and the replica step law
        let a = ExperimentConfig::default_mnist_like();
        for (key, val) in [
            ("downlink", "delta"),
            ("fanout", "tree"),
            ("branching", "5"),
            ("gamma", "0.07"),
            ("gamma_decay", "0.999"),
            ("clip", "1.5"),
        ] {
            let mut b = a.clone();
            b.set(key, val).unwrap();
            assert_ne!(
                a.wire_fingerprint(),
                b.wire_fingerprint(),
                "{key} must enter the fingerprint"
            );
        }
    }

    #[test]
    fn uplink_key_parses_validates_and_moves_fingerprint() {
        let mut c = ExperimentConfig::default_mnist_like();
        assert_eq!(c.uplink, "forward");
        // aggregate needs a sum-shaped rule + mean aggregator + a
        // data-level (or no) attack
        assert!(c.set("uplink", "aggregate").is_err());
        c.algorithm = Algorithm::Dgd;
        c.aggregator = "mean".into();
        c.attack = "labelflip".into();
        c.set("uplink", "aggregate").unwrap();
        c.validate().unwrap();
        assert!(c.set("uplink", "fold").is_err());
        assert_eq!(c.uplink, "aggregate", "a rejected set must not clobber");

        // robust rules, payload attacks, silent byzantine slots, churn,
        // lyapunov and branching < 2 all refuse the aggregated uplink
        let mut r = c.clone();
        r.algorithm = Algorithm::RoSdhb;
        assert!(r.validate().is_err());
        let mut r = c.clone();
        r.aggregator = "cwtm".into();
        assert!(r.validate().is_err());
        let mut r = c.clone();
        r.attack = "alie:1.5".into();
        assert!(r.validate().is_err());
        let mut r = c.clone();
        r.attack = "none".into();
        assert!(r.validate().is_err(), "silent byz slots would stall");
        r.n_byz = 0;
        r.validate().unwrap();
        let mut r = c.clone();
        r.epoch_rounds = 4;
        r.churn = "1:-2".into();
        assert!(r.validate().is_err());
        let mut r = c.clone();
        r.branching = 1;
        r.fanout = "flat".into();
        assert!(r.validate().is_err());
        let mut r = c.clone();
        r.algorithm = Algorithm::RobustDgd;
        r.lyapunov = true;
        assert!(r.validate().is_err());

        // the mode pins the f32 summation order: it must move the wire
        // fingerprint so both sides fold identically
        let mut fwd = c.clone();
        fwd.uplink = "forward".into();
        assert_ne!(c.wire_fingerprint(), fwd.wire_fingerprint());

        let doc = toml::TomlDoc::parse(
            "[experiment]\nalgorithm = \"dgd\"\naggregator = \"mean\"\n\
             attack = \"labelflip\"\nuplink = \"aggregate\"\n\
             fanout = \"tree\"\nbranching = 3\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.uplink, "aggregate");
        c.validate().unwrap();
    }

    #[test]
    fn io_key_parses_validates_and_stays_out_of_fingerprint() {
        let mut c = ExperimentConfig::default_mnist_like();
        assert_eq!(c.io, "threads");
        c.set("io", "evloop").unwrap();
        assert_eq!(c.io, "evloop");
        c.validate().unwrap();
        assert!(c.set("io", "tokio").is_err());
        assert_eq!(c.io, "evloop", "a rejected set must not clobber");

        let doc = toml::TomlDoc::parse("[experiment]\nio = \"evloop\"\n")
            .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.io, "evloop");

        // io is a delivery-path choice, not wire identity: both runtimes
        // produce bit-identical results, so it must NOT move the
        // fingerprint (a threads coordinator accepts evloop workers under
        // flat fan-out)
        let a = ExperimentConfig::default_mnist_like();
        let mut b = a.clone();
        b.io = "evloop".into();
        assert_eq!(a.wire_fingerprint(), b.wire_fingerprint());
    }

    #[test]
    fn telemetry_keys_parse_and_stay_out_of_fingerprint() {
        let mut c = ExperimentConfig::default_mnist_like();
        assert!(c.trace_path.is_empty(), "tracing must default off");
        assert!(c.status_addr.is_empty(), "status endpoint defaults off");
        assert!(!c.forensics, "forensics must default off");
        assert_eq!(
            c.status_history,
            crate::telemetry::status::DEFAULT_HISTORY_DEPTH
        );
        c.set("trace_path", "/tmp/run.jsonl").unwrap();
        c.set("status_addr", "127.0.0.1:7900").unwrap();
        c.set("forensics", "true").unwrap();
        c.set("status_history", "16").unwrap();
        assert_eq!(c.trace_path, "/tmp/run.jsonl");
        assert_eq!(c.status_addr, "127.0.0.1:7900");
        assert!(c.forensics);
        assert_eq!(c.status_history, 16);
        c.validate().unwrap();

        let doc = toml::TomlDoc::parse(
            "[experiment]\ntrace_path = \"t.jsonl\"\nstatus_addr = \"127.0.0.1:0\"\n\
             forensics = true\nstatus_history = 8\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.trace_path, "t.jsonl");
        assert_eq!(c.status_addr, "127.0.0.1:0");
        assert!(c.forensics);
        assert_eq!(c.status_history, 8);

        // telemetry is observation, not wire identity: a traced
        // coordinator must admit untraced workers, so neither key may
        // move the fingerprint
        let a = ExperimentConfig::default_mnist_like();
        let mut b = a.clone();
        b.trace_path = "/tmp/elsewhere.jsonl".into();
        b.status_addr = "0.0.0.0:9999".into();
        b.forensics = true;
        b.status_history = 7;
        assert_eq!(a.wire_fingerprint(), b.wire_fingerprint());
    }

    #[test]
    fn wire_fingerprint_tracks_training_state_fields() {
        let a = ExperimentConfig::default_mnist_like();
        let mut b = a.clone();
        assert_eq!(a.wire_fingerprint(), b.wire_fingerprint());
        // transport plumbing does not change the fingerprint (the same
        // run can listen on different interfaces)
        b.listen_addr = "0.0.0.0:9999".into();
        assert_eq!(a.wire_fingerprint(), b.wire_fingerprint());
        // anything feeding shards/RNG/wire plan does
        b.seed += 1;
        assert_ne!(a.wire_fingerprint(), b.wire_fingerprint());
        let mut c = a.clone();
        c.k_frac = 0.25;
        assert_ne!(a.wire_fingerprint(), c.wire_fingerprint());
        // the compressor picks the rosdhb-u wire plan (randk vs qsgd)
        let mut q = a.clone();
        q.compressor = "randk".into();
        assert_ne!(a.wire_fingerprint(), q.wire_fingerprint());
        // dataset *kind* is identity, its local path is not — the same
        // MNIST files may live at different locations across hosts
        let mut m1 = a.clone();
        m1.dataset = Dataset::MnistIdx("/data/mnist".into());
        let mut m2 = a.clone();
        m2.dataset = Dataset::MnistIdx("/home/user/mnist".into());
        assert_eq!(m1.wire_fingerprint(), m2.wire_fingerprint());
        assert_ne!(a.wire_fingerprint(), m1.wire_fingerprint());
    }

    #[test]
    fn epoch_keys_parse_validate_and_fingerprint() {
        let mut c = ExperimentConfig::default_mnist_like();
        assert_eq!(c.epoch_rounds, 0);
        assert_eq!(c.readmit, "next-epoch");
        assert_eq!(c.churn, "");
        c.set("epoch_rounds", "4").unwrap();
        assert_eq!(c.epoch_rounds, 4);
        c.set("readmit", "never").unwrap();
        assert!(c.set("readmit", "sometimes").is_err());
        c.set("churn", "1:-2,2:+2").unwrap();
        assert_eq!(c.churn, "1:-2,2:+2");

        // churn without epochs is meaningless
        let mut c = ExperimentConfig::default_mnist_like();
        c.churn = "1:-2".into();
        assert!(c.validate().is_err());
        c.epoch_rounds = 4;
        c.validate().unwrap();
        // churn slots must be honest gradient slots
        c.churn = "1:-10".into();
        assert!(c.validate().is_err());
        // DASHA's client-side estimates cannot survive a worker rebuild
        let mut c = ExperimentConfig::default_mnist_like();
        c.attack = "none".into();
        c.algorithm = Algorithm::ByzDashaPage;
        c.epoch_rounds = 4;
        assert!(c.validate().is_err());

        // epoch_rounds and readmit are wire identity; the churn schedule
        // is coordinator-local and deliberately NOT fingerprinted
        let a = ExperimentConfig::default_mnist_like();
        let mut b = a.clone();
        b.epoch_rounds = 4;
        assert_ne!(a.wire_fingerprint(), b.wire_fingerprint());
        let mut b = a.clone();
        b.readmit = "never".into();
        assert_ne!(a.wire_fingerprint(), b.wire_fingerprint());
        let mut b = a.clone();
        b.epoch_rounds = 4;
        let mut b2 = b.clone();
        b2.churn = "1:-2".into();
        assert_eq!(b.wire_fingerprint(), b2.wire_fingerprint());

        let doc = toml::TomlDoc::parse(
            "[experiment]\nepoch_rounds = 3\nreadmit = \"never\"\n\
             churn = \"1:+0\"\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.epoch_rounds, 3);
        assert_eq!(c.readmit, "never");
        assert_eq!(c.churn, "1:+0");
    }

    #[test]
    fn churn_schedules_parse_exactly() {
        assert_eq!(parse_churn("").unwrap(), vec![]);
        assert_eq!(
            parse_churn("2:+1, 1:-2").unwrap(),
            vec![
                ChurnEvent { epoch: 1, slot: 2, join: false },
                ChurnEvent { epoch: 2, slot: 1, join: true },
            ]
        );
        assert!(parse_churn("0:-1").is_err(), "epoch 0 has no boundary");
        assert!(parse_churn("1:2").is_err(), "missing +/- prefix");
        assert!(parse_churn("x:-1").is_err());
        assert!(parse_churn("1:-x").is_err());
        assert!(parse_churn("1").is_err());
    }

    #[test]
    fn algorithm_names_roundtrip() {
        for a in [
            Algorithm::RoSdhb,
            Algorithm::RoSdhbLocal,
            Algorithm::RoSdhbU,
            Algorithm::ByzDashaPage,
            Algorithm::RobustDgd,
            Algorithm::DgdRandK,
            Algorithm::Dgd,
        ] {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
    }
}
