//! Appendix C ablation: RoSDHB-U with QSGD quantization vs RandK
//! sparsification (both unbiased, Definition C.1), at matched wire
//! budgets, on the MNIST-like task under ALIE.
//!
//! Reported: uplink bytes per round, rounds/bytes to τ, best accuracy —
//! plus each compressor's variance parameter α (the quantity Appendix C's
//! rate depends on).
//!
//! Run: `cargo bench --bench bench_appendix_c`

use rosdhb::compression::qsgd::parse_spec;
use rosdhb::config::{Algorithm as AlgoId, ExperimentConfig};
use rosdhb::coordinator::Trainer;

fn main() {
    println!("# Appendix C: unbiased compressors under RoSDHB-U (f=3, ALIE)");
    println!("# d = 11809; wire budgets: qsgd:4 ≈ 5.9 KB, randk(k/d=0.12) ≈ 5.9 KB, dense = 47.2 KB");
    println!("compressor,alpha,uplink_bytes_per_round_per_worker,rounds_to_tau,uplink_bytes_to_tau,best_acc");

    // qsgd:4 wire = 4 + d/8 + 3d/8 bytes ≈ 0.5·d; randk at k/d=0.115
    // costs ~ the same (4k payload + 4k mask index bytes ≈ 0.92·k·8).
    for (comp, kf) in [("qsgd:4", 0.12), ("qsgd:1", 0.12), ("randk", 0.12)] {
        let mut cfg = ExperimentConfig::default_mnist_like();
        cfg.algorithm = AlgoId::RoSdhbU;
        cfg.compressor = comp.into();
        cfg.k_frac = kf;
        cfg.n_byz = 3;
        cfg.attack = "alie".into();
        cfg.aggregator = "nnm+cwtm".into();
        cfg.gamma = 0.4;
        cfg.gamma_decay = 0.999;
        cfg.clip = 5.0;
        cfg.rounds = 1200;
        cfg.eval_every = 10;
        cfg.train_size = 10_000;
        cfg.test_size = 1_500;
        cfg.stop_at_tau = true;
        let alpha = parse_spec(comp, 11_809, kf).unwrap().alpha();
        let r = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let per_round = r.uplink_bytes / r.rounds_run.max(1) as u64
            / cfg.n_total() as u64;
        println!(
            "{comp},{alpha:.2},{per_round},{},{},{:.4}",
            r.rounds_to_tau.map_or(-1, |v| v as i64),
            r.uplink_bytes_to_tau.map_or(-1, |v| v as i64),
            r.best_acc.unwrap_or(0.0)
        );
    }

    println!("# shape: both unbiased compressors must reach τ under attack;");
    println!("# qsgd:1 (ternary, α≈{:.0}) trades bytes for slower rounds.",
             parse_spec("qsgd:1", 11_809, 0.1).unwrap().alpha());
}
