//! Gradient compression: RandK sparsification (global & local), the TopK
//! biased baseline, and the wire codecs for masks.
//!
//! The central object is [`Mask`]: a sorted set of `k` coordinate indices
//! out of `d`. Under **global** sparsification (Algorithm 1) the server
//! draws one mask per round and broadcasts only its *seed*; workers and
//! server re-derive the identical mask deterministically
//! ([`randk::mask_from_seed`]). Under **local** sparsification (§3.3) each
//! worker draws its own mask and must ship it ([`codec::MaskWire`]).
//!
//! [`payload`] lifts every compressor's output to a typed, byte-exact
//! [`Payload`] (sparse / dense / QSGD-quantized) with a worker-side
//! [`CompressorState`], so the same object drives the in-memory byte
//! model, the TCP wire format and the in-place server arithmetic.

pub mod codec;
pub mod payload;
pub mod qsgd;
pub mod randk;
pub mod topk;

pub use payload::{CompressorState, Payload, PayloadPlan};
pub use qsgd::{CompressorSpec, Qsgd, UnbiasedCompressor};
pub use randk::{mask_from_seed, RandK};
pub use topk::TopK;

/// A sparsification mask: `k` sorted, distinct coordinates in `[0, d)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mask {
    pub d: usize,
    /// Sorted ascending, distinct.
    pub idx: Vec<u32>,
}

impl Mask {
    pub fn new(d: usize, mut idx: Vec<u32>) -> Self {
        idx.sort_unstable();
        idx.dedup();
        if let Some(&last) = idx.last() {
            assert!((last as usize) < d);
        }
        Mask { d, idx }
    }

    /// Full mask (k = d): the identity compressor.
    pub fn dense(d: usize) -> Self {
        Mask {
            d,
            idx: (0..d as u32).collect(),
        }
    }

    pub fn k(&self) -> usize {
        self.idx.len()
    }

    /// Unbiasing factor α = d/k.
    pub fn alpha(&self) -> f32 {
        self.d as f32 / self.k() as f32
    }

    /// Extract the masked coordinates of `g` in index order — the payload
    /// C_k(g) a worker uploads (Algorithm 1, step 3c).
    pub fn compress(&self, g: &[f32]) -> Vec<f32> {
        debug_assert_eq!(g.len(), self.d);
        self.idx.iter().map(|&i| g[i as usize]).collect()
    }

    /// Non-allocating variant of [`Self::compress`].
    pub fn compress_into(&self, g: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(g.len(), self.d);
        out.clear();
        out.extend(self.idx.iter().map(|&i| g[i as usize]));
    }

    /// Reconstruct the unbiased estimate `g̃ = (d/k) · scatter(values)`
    /// (Algorithm 1, step 4). Matches `kernels/ref.py: masked_scale_ref`.
    pub fn reconstruct(&self, values: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.d];
        self.reconstruct_into(values, &mut out);
        out
    }

    /// Non-allocating variant; `out` must have length `d`.
    pub fn reconstruct_into(&self, values: &[f32], out: &mut [f32]) {
        debug_assert_eq!(values.len(), self.k());
        debug_assert_eq!(out.len(), self.d);
        out.fill(0.0);
        let a = self.alpha();
        for (&i, &v) in self.idx.iter().zip(values) {
            out[i as usize] = a * v;
        }
    }

    /// Apply the mask in place **without** unbiasing (used by diagnostics:
    /// `g ⊙ mask`).
    pub fn project(&self, g: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.d];
        for &i in &self.idx {
            out[i as usize] = g[i as usize];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compress_reconstruct_roundtrip_at_k_eq_d() {
        let g = vec![1.0, -2.0, 3.0];
        let m = Mask::dense(3);
        assert_eq!(m.alpha(), 1.0);
        assert_eq!(m.reconstruct(&m.compress(&g)), g);
    }

    #[test]
    fn reconstruct_scales_by_alpha() {
        let g = vec![1.0, -2.0, 3.0, 4.0];
        let m = Mask::new(4, vec![1, 3]);
        let payload = m.compress(&g);
        assert_eq!(payload, vec![-2.0, 4.0]);
        let rec = m.reconstruct(&payload);
        assert_eq!(rec, vec![0.0, -4.0, 0.0, 8.0]); // alpha = 2
    }

    #[test]
    fn new_sorts_and_dedups() {
        let m = Mask::new(10, vec![7, 2, 7, 0]);
        assert_eq!(m.idx, vec![0, 2, 7]);
    }

    #[test]
    fn project_keeps_unscaled() {
        let g = vec![1.0, 2.0, 3.0];
        let m = Mask::new(3, vec![2]);
        assert_eq!(m.project(&g), vec![0.0, 0.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_index_panics() {
        let _ = Mask::new(3, vec![3]);
    }
}
