//! Table 1 — convergence-rate comparison on the controlled quadratic
//! world (exact G, B, L): RoSDHB vs Byz-DASHA-PAGE vs SOTA-no-compression
//! (robust DGD) vs SOTA-no-robustness (DGD+RandK).
//!
//! For each algorithm we report E‖∇L_H(θ̂)‖² after T rounds at several T
//! and compression levels α — the quantity Table 1 bounds. Expected
//! *shape* (paper, §3.2):
//!
//! * RoSDHB & Byz-DASHA-PAGE: ~α/T decay toward a κG²-sized floor,
//!   insensitive to δ = f/n in the decaying term;
//! * robust-DGD (α = 1): same floor, 1/T decay without the α factor;
//! * DGD+RandK (f = 0 column): α/T decay to ~0 floor; under attack it has
//!   no floor at all — it diverges/stalls (κ = ∞).
//!
//! Run: `cargo bench --bench bench_table1`

use rosdhb::aggregators;
use rosdhb::aggregators::geometry::RefreshPeriod;
use rosdhb::algorithms::{
    baselines, dasha, rosdhb::RoSdhb, Algorithm, RoundEnv, UplinkCtx,
};
use rosdhb::attacks::{parse_spec as parse_attack, AttackKind};
use rosdhb::prng::Pcg64;
use rosdhb::synthetic::QuadraticWorld;
use rosdhb::tensor;
use rosdhb::transport::ByteMeter;

const D: usize = 128;
const NH: usize = 10;
const F: usize = 2;
const G: f32 = 1.5;
const B: f32 = 0.3;
const MU: f32 = 1.0;

struct Run {
    alg: Box<dyn Algorithm>,
    gamma: f32,
    k: usize,
    attack: AttackKind,
    aggregator: Box<dyn aggregators::Aggregator>,
    n_byz: usize,
}

fn grad_h_sq_at(run: &mut Run, world: &QuadraticWorld, t_max: u64, probes: &[u64]) -> Vec<f64> {
    let mut theta = vec![2.0f32; D];
    let mut meter = ByteMeter::new(NH + run.n_byz);
    let mut rng = Pcg64::new(99, 99);
    let mut out = Vec::new();
    for t in 1..=t_max {
        let grads = world.grads(&theta);
        let mut env = RoundEnv {
            d: D,
            n_honest: NH,
            n_byz: run.n_byz,
            seed: 5,
            k: run.k,
            beta: 0.9,
            aggregator: run.aggregator.as_ref(),
            geometry_refresh: RefreshPeriod::DEFAULT,
            attack: &run.attack,
            meter: &mut meter,
            rng: &mut rng,
            payloads: None,
            uplink: UplinkCtx::Forward,
        };
        let r = run.alg.round(t, &grads, &[], &mut env);
        tensor::axpy(&mut theta, -run.gamma, &r);
        if probes.contains(&t) {
            out.push(tensor::norm_sq(&world.grad_h(&theta)));
        }
    }
    out
}

fn main() {
    let world = QuadraticWorld::new(D, NH, MU, B, G, 21);
    let probes = [50u64, 200, 800, 3000];
    let n = NH + F;
    println!("# Table 1 reproduction: E||grad_H||^2 vs T (quadratics, G={G}, B={B}, L={MU})");
    println!("# floor reference: kappa*G^2 with kappa(nnm+cwtm, n={n}, f={F})");
    let kappa = aggregators::parse_spec("nnm+cwtm", F)
        .unwrap()
        .kappa(n, F);
    println!("# kappa bound = {kappa:.4} -> kappa*G^2 = {:.4}", kappa * (G as f64).powi(2));
    println!("algorithm,alpha,attack,T50,T200,T800,T3000");

    let mk_agg = || aggregators::parse_spec("nnm+cwtm", F).unwrap();
    let mk_mean = || aggregators::parse_spec("mean", 0).unwrap();

    // RoSDHB at alpha in {1, 4, 16} under ALIE
    for &k in &[D, D / 4, D / 16] {
        let mut run = Run {
            alg: Box::new(RoSdhb::new(D, n, false)),
            gamma: 0.08 * k as f32 / D as f32,
            k,
            attack: parse_attack("alie").unwrap(),
            aggregator: mk_agg(),
            n_byz: F,
        };
        let vals = grad_h_sq_at(&mut run, &world, 3000, &probes);
        print_row("rosdhb", D as f64 / k as f64, "alie", &vals);
    }
    // Byz-DASHA-PAGE at the same alphas
    for &k in &[D, D / 4, D / 16] {
        let mut run = Run {
            alg: Box::new(dasha::ByzDashaPage::new(D, n)),
            gamma: 0.08 * k as f32 / D as f32,
            k,
            attack: parse_attack("alie").unwrap(),
            aggregator: mk_agg(),
            n_byz: F,
        };
        let vals = grad_h_sq_at(&mut run, &world, 3000, &probes);
        print_row("byz-dasha-page", D as f64 / k as f64, "alie", &vals);
    }
    // SOTA no compression: robust DGD (alpha = 1)
    {
        let mut run = Run {
            alg: Box::new(baselines::RobustDgd::new(D, n)),
            gamma: 0.08,
            k: D,
            attack: parse_attack("alie").unwrap(),
            aggregator: mk_agg(),
            n_byz: F,
        };
        let vals = grad_h_sq_at(&mut run, &world, 3000, &probes);
        print_row("robust-dgd", 1.0, "alie", &vals);
    }
    // SOTA no robustness: DGD+RandK with f = 0
    for &k in &[D / 4, D / 16] {
        let mut run = Run {
            alg: Box::new(baselines::DgdRandK::new()),
            gamma: 0.08 * k as f32 / D as f32,
            k,
            attack: AttackKind::None,
            aggregator: mk_mean(),
            n_byz: 0,
        };
        let vals = grad_h_sq_at(&mut run, &world, 3000, &probes);
        print_row("dgd-randk(f=0)", D as f64 / k as f64, "none", &vals);
    }
    // Negative control: DGD+RandK UNDER attack (mean aggregation) — the
    // "naive combination degrades" motivation.
    {
        let mut run = Run {
            alg: Box::new(baselines::DgdRandK::new()),
            gamma: 0.02,
            k: D / 4,
            attack: parse_attack("alie").unwrap(),
            aggregator: mk_mean(),
            n_byz: F,
        };
        let vals = grad_h_sq_at(&mut run, &world, 3000, &probes);
        print_row("dgd-randk(attacked)", 4.0, "alie", &vals);
    }

    // wall-clock of one 3000-round run for the perf log
    let t0 = std::time::Instant::now();
    let mut run = Run {
        alg: Box::new(RoSdhb::new(D, n, false)),
        gamma: 0.02,
        k: D / 4,
        attack: parse_attack("alie").unwrap(),
        aggregator: mk_agg(),
        n_byz: F,
    };
    let _ = grad_h_sq_at(&mut run, &world, 3000, &probes);
    println!(
        "# timing: 3000 rosdhb rounds (d={D}, n={n}) in {:.3}s",
        t0.elapsed().as_secs_f64()
    );
}

fn print_row(name: &str, alpha: f64, attack: &str, vals: &[f64]) {
    print!("{name},{alpha},{attack}");
    for v in vals {
        print!(",{v:.5e}");
    }
    println!();
}
