//! Aggregated-uplink integration tests (PR 9): partial aggregation on
//! the relay tree (`uplink = "aggregate"`) over loopback TCP.
//!
//! * a tree-aggregated run is bit-identical — per-round log included —
//!   to the local oracle with the same config, and its *trajectory*
//!   (losses, norms, accuracy) is bit-identical to the flat-aggregated
//!   run (byte columns differ by construction: subtree frames vs
//!   singletons);
//! * measured socket bytes equal the `ByteMeter` model on **both**
//!   uplink directions: coordinator ingress on the coordinator's
//!   sockets, the rest folded worker-to-worker through the relay tree
//!   and reported per worker in `JoinSummary::relayed_uplink_wire_bytes`;
//! * this holds for every sum/mean-shaped rule the mode admits: dgd,
//!   robust-dgd (server-side momentum over the summed gradient) and
//!   byz-dasha-page (sparse union-of-masks estimate frames);
//! * a mid-run relay-worker crash degrades its children to direct
//!   AGG delivery (RESYNC) without losing contributions — the run stays
//!   trajectory-identical to flat aggregation with the same crash;
//! * a pure-library property sweep re-nests every subtree shape
//!   (branching 2/3/n, vacant slots and silent/evicted nodes at every
//!   depth, dense and sparse values) and demands bit-parity between the
//!   physical relay fold and the flat singleton oracle wherever frames
//!   stay whole-subtree/singleton (the steady states and root/leaf
//!   deaths), and lossless closeness for the one-round partial-subtree
//!   shapes a mid-round interior crash produces.

use rosdhb::config::{Algorithm, ExperimentConfig};
use rosdhb::coordinator::round_transport::TcpTransport;
use rosdhb::coordinator::{RunReport, Trainer};
use rosdhb::model::MlpSpec;
use rosdhb::transport::net::CoordinatorServer;
use rosdhb::transport::uplink::{
    combine, combine_slot_values, relay_fold, AggFrame, AggValue, ReducePlan,
};
use rosdhb::worker::remote::{join_run, JoinOpts, JoinSummary};
use std::thread;
use std::time::Duration;

fn base_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default_mnist_like();
    c.algorithm = Algorithm::Dgd;
    c.aggregator = "mean".into();
    c.uplink = "aggregate".into();
    c.n_honest = 4;
    c.n_byz = 0;
    c.attack = "none".into();
    c.k_frac = 0.1;
    c.rounds = 6;
    c.eval_every = 2;
    c.batch = 30;
    c.train_size = 600;
    c.test_size = 200;
    c.stop_at_tau = false;
    c.seed = 7;
    c.transport = "tcp".into();
    c.round_timeout_ms = 20_000;
    c
}

/// Run `cfg` over loopback TCP: one coordinator on this thread, one
/// worker thread per entry of `worker_caps` (a cap injects a mid-run
/// crash after that many rounds).
fn run_tcp(
    cfg: &ExperimentConfig,
    worker_caps: &[Option<u64>],
) -> (
    RunReport,
    rosdhb::transport::net::NetStats,
    Vec<anyhow::Result<JoinSummary>>,
) {
    assert_eq!(worker_caps.len(), cfg.n_total());
    let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handles: Vec<_> = worker_caps
        .iter()
        .map(|cap| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            let cap = *cap;
            thread::spawn(move || {
                join_run(
                    &cfg,
                    &addr,
                    Duration::from_secs(30),
                    JoinOpts {
                        max_rounds: cap,
                        ..Default::default()
                    },
                )
            })
        })
        .collect();
    let d = MlpSpec::default().p();
    let transport = TcpTransport::rendezvous(server, cfg, d).unwrap();
    let mut trainer = Trainer::with_transport(cfg, Box::new(transport)).unwrap();
    let report = trainer.run().unwrap();
    let stats = trainer.net_stats().unwrap();
    trainer.shutdown_transport(); // BYE — releases the worker threads
    let outcomes = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (report, stats, outcomes)
}

fn run_local(cfg: &ExperimentConfig) -> RunReport {
    let mut local = cfg.clone();
    local.transport = "local".into();
    let mut t = Trainer::from_config(&local).unwrap();
    t.run().unwrap()
}

/// Every field that must match for "bit-identical RunReport" (the
/// ingress/relayed uplink split included — the local oracle models the
/// same reduce tree).
fn assert_reports_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.algorithm, b.algorithm);
    assert_eq!(a.rounds_run, b.rounds_run);
    assert_eq!(a.rounds_to_tau, b.rounds_to_tau);
    assert_eq!(a.uplink_bytes_to_tau, b.uplink_bytes_to_tau);
    assert_eq!(a.uplink_bytes, b.uplink_bytes);
    assert_eq!(a.coordinator_ingress_bytes, b.coordinator_ingress_bytes);
    assert_eq!(a.relayed_uplink_bytes, b.relayed_uplink_bytes);
    assert_eq!(a.downlink_bytes, b.downlink_bytes);
    assert_eq!(a.coordinator_egress_bytes, b.coordinator_egress_bytes);
    assert_eq!(a.best_acc, b.best_acc);
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(a.log.rows.len(), b.log.rows.len());
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.update_norm, rb.update_norm, "round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "round {}", ra.round);
        assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "round {}", ra.round);
        assert_eq!(ra.downlink_bytes, rb.downlink_bytes, "round {}", ra.round);
    }
}

/// The learning trajectory alone — what must agree across *different*
/// topologies of the same reduction (flat vs tree frames carry different
/// byte counts by design).
fn assert_trajectory_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.rounds_run, b.rounds_run);
    assert_eq!(a.best_acc, b.best_acc);
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(a.log.rows.len(), b.log.rows.len());
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.update_norm, rb.update_norm, "round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "round {}", ra.round);
    }
}

#[test]
fn tcp_aggregate_flat_is_bit_identical_with_full_ingress() {
    // Flat fan-out + aggregated uplink: every worker ships a singleton
    // AGG frame straight to the coordinator, so ingress == uplink and
    // nothing is relayed.
    let cfg = base_cfg();
    let (report, stats, outcomes) = run_tcp(&cfg, &[None; 4]);
    for o in &outcomes {
        let s = o.as_ref().expect("worker must finish cleanly");
        assert_eq!(s.rounds, cfg.rounds as u64);
        assert_eq!(s.role, "honest");
        assert_eq!(s.relayed_uplink_wire_bytes, 0, "flat relays nothing");
    }
    let local = run_local(&cfg);
    assert_reports_identical(&report, &local);
    assert_eq!(report.coordinator_ingress_bytes, report.uplink_bytes);
    assert_eq!(report.relayed_uplink_bytes, 0);
    // measured socket bytes == the model, uplink direction
    assert_eq!(stats.wire_uplink, report.coordinator_ingress_bytes);
}

#[test]
fn tcp_aggregate_tree_matches_local_oracle_for_every_sum_rule() {
    // n = 8, branching 2: positions 0..7 with children(0) = {2,3},
    // children(1) = {4,5}, children(2) = {6,7} — position 2 is a
    // *non-root* interior relay, so the fold nests two levels deep.
    for alg in ["dgd", "robust-dgd", "dasha"] {
        let mut cfg = base_cfg();
        cfg.algorithm = Algorithm::parse(alg).unwrap();
        cfg.n_honest = 8;
        cfg.fanout = "tree".into();
        cfg.branching = 2;
        let (tree, stats, outcomes) = run_tcp(&cfg, &[None; 8]);
        let summaries: Vec<&JoinSummary> =
            outcomes.iter().map(|o| o.as_ref().unwrap()).collect();
        for s in &summaries {
            assert_eq!(s.rounds, cfg.rounds as u64, "{alg}");
        }

        // bit-identical to the local oracle modeling the same tree
        let local = run_local(&cfg);
        assert_reports_identical(&tree, &local);

        // and trajectory-identical to the flat-aggregated reduction:
        // the re-nested fold must reproduce the flat association bits
        let mut flat_cfg = cfg.clone();
        flat_cfg.fanout = "flat".into();
        let (flat, _, _) = run_tcp(&flat_cfg, &[None; 8]);
        assert_trajectory_identical(&tree, &flat);

        // byte split: only root subtree frames reach the coordinator…
        assert!(
            tree.coordinator_ingress_bytes < tree.uplink_bytes,
            "{alg}: tree aggregation must fold bytes below the root"
        );
        assert_eq!(
            stats.wire_uplink, tree.coordinator_ingress_bytes,
            "{alg}: measured coordinator ingress"
        );
        // …and the rest shows up, byte-exact, on the interior relays
        let relayed: u64 = summaries
            .iter()
            .map(|s| s.relayed_uplink_wire_bytes)
            .sum();
        assert_eq!(
            relayed,
            tree.uplink_bytes - tree.coordinator_ingress_bytes,
            "{alg}: relayed uplink must close the byte identity"
        );
        assert_eq!(tree.relayed_uplink_bytes, relayed, "{alg}");
        assert!(relayed > 0, "{alg}: interior relays must have folded");
        let relayed_raw: u64 = summaries
            .iter()
            .map(|s| s.relayed_uplink_raw_bytes)
            .sum();
        assert!(
            relayed_raw > relayed,
            "{alg}: raw bytes include the frame envelopes"
        );
    }
}

#[test]
fn tcp_aggregate_tree_relay_crash_degrades_without_losing_contributions() {
    // Worker 0 is a tree root relaying slots 2 and 3. It crashes after 2
    // rounds: its children must fall back to direct AGG delivery within
    // the round and keep contributing — the run stays
    // trajectory-identical to flat aggregation with the identical crash
    // (the re-nested combine folds the same covered slots in the same
    // order, whatever mix of subtree frames and singletons arrives).
    let mut tree = base_cfg();
    tree.n_honest = 5;
    tree.rounds = 5;
    // a dead socket is detected by the I/O threads, not the deadline —
    // a long timeout must not slow the surviving rounds
    tree.round_timeout_ms = 60_000;
    tree.fanout = "tree".into();
    tree.branching = 2;
    let caps = [Some(2), None, None, None, None];
    let (tree_report, _stats, tree_outcomes) = run_tcp(&tree, &caps);
    assert_eq!(tree_outcomes[0].as_ref().unwrap().rounds, 2);
    assert_eq!(tree_report.rounds_run, 5);

    let mut flat = tree.clone();
    flat.fanout = "flat".into();
    let (flat_report, _stats, flat_outcomes) = run_tcp(&flat, &caps);
    assert_eq!(flat_outcomes[0].as_ref().unwrap().rounds, 2);
    assert_eq!(flat_report.rounds_run, 5);

    assert_trajectory_identical(&tree_report, &flat_report);
    // the crash survivors kept serving every round
    for o in &tree_outcomes[1..] {
        assert_eq!(o.as_ref().unwrap().rounds, 5);
    }
}

// --------------------------------------------------------- property sweep

/// Deterministic dense value for a slot (d = 7 keeps the sweep cheap).
fn dense_value(s: u16) -> AggValue {
    AggValue::Dense(
        (0..7)
            .map(|j| (s as f32 + 1.0) * 1.25 + j as f32 * 0.375)
            .collect(),
    )
}

/// Deterministic sparse value: slot-dependent mask over 16 coordinates,
/// overlapping between slots so the union merge has float adds to get
/// wrong if the association drifted.
fn sparse_value(s: u16) -> AggValue {
    // {s, s+3, s+6, s+9} mod 16: always 4 distinct coordinates, heavily
    // overlapping between neighboring slots
    let mut idx: Vec<u32> =
        (0..4u32).map(|j| (j * 3 + s as u32) % 16).collect();
    idx.sort_unstable();
    let val = idx
        .iter()
        .map(|&c| 0.125 + c as f32 * 0.5 + s as f32 * 0.0625)
        .collect();
    AggValue::Sparse { idx, val }
}

/// Elementwise closeness for the partial-subtree cases (see the sweep):
/// shapes and sparse coordinates must still match exactly — only the
/// f32 association may differ.
fn assert_values_close(a: &Option<AggValue>, b: &Option<AggValue>, ctx: &str) {
    let close = |x: &[f32], y: &[f32]| {
        assert_eq!(x.len(), y.len(), "{ctx}");
        for (u, v) in x.iter().zip(y) {
            assert!(
                (u - v).abs() <= 1e-4 * (1.0 + v.abs()),
                "{ctx}: {u} vs {v}"
            );
        }
    };
    match (a, b) {
        (None, None) => {}
        (Some(AggValue::Dense(x)), Some(AggValue::Dense(y))) => close(x, y),
        (
            Some(AggValue::Sparse { idx: xi, val: xv }),
            Some(AggValue::Sparse { idx: yi, val: yv }),
        ) => {
            assert_eq!(xi, yi, "{ctx}: union masks diverged");
            close(xv, yv);
        }
        _ => panic!("{ctx}: value shapes differ"),
    }
}

/// The frames that physically reach the coordinator from the subtree at
/// `pos`: a live node folds its own singleton with its children's
/// subtree frames; a dead (silent/evicted) node contributes nothing and
/// its children's frames ship direct — exactly the RESYNC degradation.
fn physical_frames(
    plan: &ReducePlan,
    pos: usize,
    dead: &[u16],
    value_of: &dyn Fn(u16) -> AggValue,
) -> Vec<AggFrame> {
    let slot = plan.slot(pos);
    let mut child_frames: Vec<AggFrame> = Vec::new();
    for c in plan.children(pos) {
        child_frames.extend(physical_frames(plan, c, dead, value_of));
    }
    if dead.contains(&slot) {
        return child_frames; // children go direct past the dead relay
    }
    // a live relay folds only the frames addressed to it: each child
    // subtree's *own* frame (direct escapees from deeper crashes ride
    // along untouched — they already left the tree)
    let (to_me, escaped): (Vec<AggFrame>, Vec<AggFrame>) = child_frames
        .into_iter()
        .partition(|f| {
            let root_pos =
                plan.slots().binary_search(&f.root_slot()).unwrap();
            plan.parent(root_pos) == Some(pos)
        });
    let own = AggFrame::single(1, slot, slot as f32 * 0.5, value_of(slot));
    let folded = relay_fold(own, to_me).unwrap();
    let mut out = vec![folded];
    out.extend(escaped);
    out
}

#[test]
fn reduce_plan_property_sweep_matches_flat_oracle() {
    for &dense in &[true, false] {
        let value_of = |s: u16| -> AggValue {
            if dense {
                dense_value(s)
            } else {
                sparse_value(s)
            }
        };
        for n in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 13] {
            for b in [2usize, 3, n.max(2)] {
                // vacancy patterns: full roster, then each slot vacated
                let mut masks: Vec<Vec<bool>> = vec![vec![true; n]];
                for v in 0..n {
                    let mut m = vec![true; n];
                    m[v] = false;
                    masks.push(m);
                }
                for active in masks {
                    let plan = ReducePlan::new(b, &active);
                    if plan.n() == 0 {
                        continue;
                    }
                    // dead sets: nobody, each single node, and the
                    // first two plan slots together (a root relay plus
                    // its successor)
                    let mut dead_sets: Vec<Vec<u16>> = vec![vec![]];
                    for &s in plan.slots() {
                        dead_sets.push(vec![s]);
                    }
                    if plan.n() >= 2 {
                        dead_sets
                            .push(vec![plan.slot(0), plan.slot(1)]);
                    }
                    for dead in dead_sets {
                        let mut frames = Vec::new();
                        for r in plan.roots() {
                            frames.extend(physical_frames(
                                &plan, r, &dead, &value_of,
                            ));
                        }
                        let nested = combine(&plan, frames);
                        let oracle = combine_slot_values(&plan, |s| {
                            (!dead.contains(&s)).then(|| value_of(s))
                        });
                        let ctx = format!(
                            "n={n} b={b} active={active:?} dead={dead:?} \
                             dense={dense}"
                        );
                        // Bit-parity with the flat oracle is guaranteed
                        // exactly when every frame still covers a whole
                        // plan subtree or a singleton: a dead ROOT's
                        // children land at top level through the same
                        // recursion, a dead LEAF simply contributes
                        // nothing. A dead *interior* node with a live
                        // parent makes that parent ship a partial
                        // subtree — nothing is lost, but the f32
                        // association differs for that round (the
                        // runtime then evicts the slot, the next plan
                        // re-parents the orphans, and exact parity
                        // returns).
                        let exact = dead.iter().all(|s| {
                            let p = plan
                                .slots()
                                .binary_search(s)
                                .expect("dead sets draw from plan slots");
                            plan.is_root_slot(*s)
                                || plan.children(p).is_empty()
                        });
                        if exact {
                            assert_eq!(nested.total, oracle, "{ctx}");
                        } else {
                            assert_values_close(
                                &nested.total,
                                &oracle,
                                &ctx,
                            );
                        }
                        assert_eq!(nested.dropped, 0);
                        let mut expect_covered: Vec<u16> = plan
                            .slots()
                            .iter()
                            .copied()
                            .filter(|s| !dead.contains(s))
                            .collect();
                        expect_covered.sort_unstable();
                        assert_eq!(nested.covered, expect_covered);
                    }
                }
            }
        }
    }
}
