"""L2 model: shapes, pack/unpack round-trip, gradient correctness, loss
sanity, and determinism of init."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def _params(seed=(1, 2)):
    return model.init_params(jnp.asarray(seed, jnp.uint32))


def _batch(rng, b=model.BATCH):
    x = jnp.asarray(rng.standard_normal((b, model.D_IN)), jnp.float32)
    y = jax.nn.one_hot(jnp.asarray(rng.integers(0, 10, b)), model.CLASSES)
    return x, y


def test_param_count_matches_paper_scale():
    # Paper's CNN: 11_830 params; our MLP: 11_809 (-0.18%).
    assert model.P == 11_809
    assert abs(model.P - 11_830) / 11_830 < 0.01


def test_pack_unpack_roundtrip():
    p = _params()
    assert p.shape == (model.P,)
    np.testing.assert_array_equal(model.pack(*model.unpack(p)), p)


def test_init_deterministic_and_seed_sensitive():
    a, b = _params((1, 2)), _params((1, 2))
    np.testing.assert_array_equal(a, b)
    c = _params((3, 4))
    assert float(jnp.linalg.norm(a - c)) > 1e-3


def test_init_biases_zero():
    _, b1, _, b2 = model.unpack(_params())
    np.testing.assert_array_equal(b1, np.zeros(model.HIDDEN))
    np.testing.assert_array_equal(b2, np.zeros(model.CLASSES))


def test_forward_shapes():
    rng = np.random.default_rng(0)
    x, _ = _batch(rng)
    logits = model.forward(_params(), x)
    assert logits.shape == (model.BATCH, model.CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_log10():
    """Zero-bias random-weight init => loss within ~1 nat of ln(10)
    (random logits of O(1) scale inflate CE slightly above the uniform
    baseline; anything far beyond that signals a broken init or loss)."""
    rng = np.random.default_rng(0)
    x, y = _batch(rng)
    loss = model.loss_fn(_params(), x, y)
    assert abs(float(loss) - np.log(10.0)) < 1.2


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_grad_matches_pure_jnp(seed):
    """End-to-end grad through the Pallas layers == pure-jnp autodiff."""
    rng = np.random.default_rng(seed)
    x, y = _batch(rng)
    p = _params((seed % 1000, 5))

    def loss_ref(params):
        w1, b1, w2, b2 = model.unpack(params)
        h = jnp.maximum(x @ w1 + b1, 0.0)
        logits = h @ w2 + b2
        logp = logits - jax.nn.logsumexp(logits, -1, keepdims=True)
        return -jnp.mean(jnp.sum(y * logp, -1))

    l_got, g_got = model.loss_and_grad(p, x, y)
    l_ref, g_ref = jax.value_and_grad(loss_ref)(p)
    np.testing.assert_allclose(float(l_got), float(l_ref), rtol=1e-5)
    np.testing.assert_allclose(g_got, g_ref, rtol=1e-4, atol=1e-5)


def test_grad_numerical_spotcheck():
    """Central finite differences on a few random coordinates."""
    rng = np.random.default_rng(3)
    x, y = _batch(rng)
    p = np.asarray(_params(), np.float64)
    _, g = model.loss_and_grad(jnp.asarray(p, jnp.float32), x, y)
    eps = 1e-3

    def f(pv):
        return float(model.loss_fn(jnp.asarray(pv, jnp.float32), x, y))

    for idx in rng.choice(model.P, size=6, replace=False):
        pp, pm = p.copy(), p.copy()
        pp[idx] += eps
        pm[idx] -= eps
        fd = (f(pp) - f(pm)) / (2 * eps)
        assert abs(fd - float(g[idx])) < 5e-3, (idx, fd, float(g[idx]))


def test_training_reduces_loss():
    """300 full-batch GD steps on a fixed batch should overfit it."""
    rng = np.random.default_rng(1)
    x, y = _batch(rng)
    p = _params()
    l0, _ = model.loss_and_grad(p, x, y)
    step = jax.jit(lambda p: p - 0.5 * model.loss_and_grad(p, x, y)[1])
    for _ in range(300):
        p = step(p)
    l1, _ = model.loss_and_grad(p, x, y)
    assert float(l1) < 0.5 * float(l0), (float(l0), float(l1))
