//! Byz-DASHA-PAGE — the SOTA comparator [29], in the gradient-descent
//! specialization the paper compares against (Appendix B: p = 1, full
//! gradients each round).
//!
//! Mechanics (per [29], with p = 1 the PAGE estimator is the exact local
//! gradient and the MVR term vanishes — what remains is DASHA's
//! compressed-*difference* scheme):
//!
//! * round 0: every worker uploads its **dense** gradient
//!   (`g_i^0 = ∇L_i(θ^0)`, the theorem's initialization);
//! * round t>0: worker i uploads `c_i^t = C_i(∇L_i(θ_t) − ĝ_i^{t−1})`
//!   with an *independent* RandK mask (unbiased compressor, as in [29]);
//!   server and worker both update the estimate
//!   `ĝ_i^t = ĝ_i^{t−1} + c_i^t`;
//! * server aggregates `R^t = F(ĝ_1^t, …, ĝ_n^t)`.
//!
//! As θ_t converges the differences shrink, so compression noise shrinks —
//! the variance-reduction effect that made Byz-DASHA-PAGE robust, at the
//! price of the bounded-Hessian-variance assumption in its analysis.
//!
//! Byzantine workers steer their server-side estimate toward the crafted
//! vector v by sending `C(v − ĝ_byz^{t−1})` (omniscient adversary: it
//! knows its own estimate).

use super::{byzantine_vectors, Algorithm, RoundEnv};
use crate::compression::codec::mask_wire_len;
use crate::compression::payload::{
    dasha_agg_contribution, dasha_apply, Payload, TAG_DASHA,
};
use crate::compression::RandK;
use crate::tensor;
use crate::transport::uplink::{
    agg_dense_payload_len, agg_sparse_payload_len, combine_slot_values,
    meter_model, AggValue,
};
use crate::transport::{
    compressed_grad_len, full_grad_len, payload_uplink_len,
};

pub struct ByzDashaPage {
    /// Server-side gradient estimates ĝ_i (identical to worker copies).
    /// Empty under `uplink = "aggregate"` over tcp — replacing these n
    /// dense rows with [`Self::agg_sum`] is that mode's memory win
    /// (pinned by `tests/test_alloc`). The local aggregate oracle lazily
    /// allocates them as its simulation of the remote worker copies.
    estimates: Vec<Vec<f32>>,
    /// Scratch: difference vector.
    diff: Vec<f32>,
    /// Scratch: compressed difference (k floats, reused — §Perf: no
    /// per-worker payload allocation on the steady-state loop).
    payload: Vec<f32>,
    initialized: bool,
    /// `uplink = "aggregate"`: the summed estimate S = Σᵢ ĝᵢ. The
    /// estimate law is additive (ĝᵢ ← ĝᵢ + uᵢ), so S advances by the
    /// folded Σᵢ uᵢ over the union of the round's masks and
    /// R^t = S/n reproduces the validated mean without any per-worker
    /// row. Empty on the value-forwarding path.
    agg_sum: Vec<f32>,
    /// Sum-mode round counters (dense re-init folds vs sparse
    /// union-advances) — the test handle mirroring the geometry engine's
    /// rebuild/incremental pins.
    agg_rebuilds: u64,
    agg_incrementals: u64,
}

impl ByzDashaPage {
    pub fn new(d: usize, n_workers: usize) -> Self {
        ByzDashaPage {
            estimates: vec![vec![0.0; d]; n_workers],
            diff: vec![0.0; d],
            payload: Vec::new(),
            initialized: false,
            agg_sum: Vec::new(),
            agg_rebuilds: 0,
            agg_incrementals: 0,
        }
    }

    /// Sum-mode constructor (`uplink = "aggregate"`): no n×d estimate
    /// matrix — only the running sum S.
    pub fn new_aggregate(d: usize) -> Self {
        ByzDashaPage {
            estimates: Vec::new(),
            diff: vec![0.0; d],
            payload: Vec::new(),
            initialized: false,
            agg_sum: vec![0.0; d],
            agg_rebuilds: 0,
            agg_incrementals: 0,
        }
    }

    /// `(dense re-init rounds, sparse union-advance rounds)` so far —
    /// meaningful under `uplink = "aggregate"` only.
    pub fn agg_counters(&self) -> (u64, u64) {
        (self.agg_rebuilds, self.agg_incrementals)
    }

    /// `uplink = "aggregate"` round: advance the single summed estimate
    /// S = Σᵢ ĝᵢ instead of n dense rows. The first round (and k = d)
    /// folds dense gradients and rebuilds S outright; every later round
    /// folds the workers' sparse scaled-difference updates over the
    /// union of their masks and S advances in place — `S += Σᵢ uᵢ`
    /// follows from `ĝᵢ += uᵢ` by linearity. R^t = S/n under the
    /// `aggregator = "mean"` the mode's validation pins.
    fn round_aggregate(
        &mut self,
        t: u64,
        honest_grads: &[Vec<f32>],
        byz_grads: &[Vec<f32>],
        env: &mut RoundEnv,
    ) -> Vec<f32> {
        let d = env.d;
        let n = env.n_total();
        let (plan, wire, physical_tree) = env.uplink.take_parts();
        let dense_round = !self.initialized || env.k == d;

        // Per-slot masks re-derived from the shared derived streams —
        // the identical draw every remote worker makes, so the modeled
        // union-of-masks payload sizes match the wire bytes exactly
        // (and the local fold below compresses with the same masks).
        let slot_masks: Option<Vec<Vec<u32>>> = if dense_round {
            None
        } else {
            let rk = RandK { d, k: env.k };
            Some(
                plan.slots()
                    .iter()
                    .map(|&s| {
                        let mut wrng =
                            env.rng.derive(TAG_DASHA, t, s as u64);
                        rk.draw(&mut wrng).idx
                    })
                    .collect(),
            )
        };
        match &slot_masks {
            None => meter_model(plan, physical_tree, env.meter, |_| {
                agg_dense_payload_len(d)
            }),
            Some(masks) => {
                meter_model(plan, physical_tree, env.meter, |covered| {
                    let mut union: Vec<u32> = covered
                        .iter()
                        .filter_map(|s| plan.slots().binary_search(s).ok())
                        .flat_map(|p| masks[p].iter().copied())
                        .collect();
                    union.sort_unstable();
                    union.dedup();
                    agg_sparse_payload_len(union.len())
                })
            }
        }

        let total = match wire {
            Some(total) => total,
            None => {
                // Local oracle: simulate the per-worker estimate copies
                // in process (exactly what the remote workers keep) and
                // fold their contributions through the shared plan
                // recursion — bit-identical to the wire fold.
                if self.estimates.is_empty() {
                    self.estimates = vec![vec![0.0; d]; n];
                }
                let alpha = d as f32 / env.k as f32;
                let n_honest = env.n_honest;
                let estimates = &mut self.estimates;
                combine_slot_values(plan, |s| {
                    let w = s as usize;
                    let g: &[f32] = if w < n_honest {
                        &honest_grads[w]
                    } else {
                        &byz_grads[w - n_honest]
                    };
                    Some(match &slot_masks {
                        None => {
                            estimates[w].copy_from_slice(g);
                            AggValue::Dense(g.to_vec())
                        }
                        Some(masks) => {
                            let p = plan
                                .slots()
                                .binary_search(&s)
                                .expect("combine iterates plan slots");
                            let (idx, val) = dasha_agg_contribution(
                                &mut estimates[w],
                                &masks[p],
                                alpha,
                                g,
                            );
                            AggValue::Sparse { idx, val }
                        }
                    })
                })
            }
        };

        if dense_round {
            // Dense re-init: S is the fold itself. An uncovered slot's
            // estimate is zero by the round-0 convention, so a frame
            // lost on the init round simply contributes nothing —
            // identical to the zero estimate row it leaves behind under
            // value-forwarding.
            self.agg_sum = match total {
                Some(AggValue::Dense(v)) if v.len() == d => v,
                _ => vec![0.0; d],
            };
            self.agg_rebuilds += 1;
        } else {
            match total {
                Some(AggValue::Sparse { idx, val }) => {
                    for (&ci, &u) in idx.iter().zip(&val) {
                        self.agg_sum[ci as usize] += u;
                    }
                }
                Some(AggValue::Dense(_)) => {
                    debug_assert!(false, "dense fold on a sparse round")
                }
                None => {} // nothing covered: S carries unchanged
            }
            self.agg_incrementals += 1;
        }
        self.initialized = true;
        let mut out = self.agg_sum.clone();
        tensor::scale(&mut out, 1.0 / n as f32);
        out
    }

    fn meter_dense(&self, env: &mut RoundEnv, worker: usize) {
        env.meter.record_uplink_sized(worker, full_grad_len(env.d));
    }

    fn meter_sparse(&self, env: &mut RoundEnv, worker: usize, k: usize) {
        // local mask: payload + mask wire (size-only, §Perf)
        env.meter.record_uplink_sized(
            worker,
            compressed_grad_len(k, mask_wire_len(env.d, k)),
        );
    }
}

impl Algorithm for ByzDashaPage {
    fn name(&self) -> &'static str {
        "byz-dasha-page"
    }

    fn round(
        &mut self,
        t: u64,
        honest_grads: &[Vec<f32>],
        byz_grads: &[Vec<f32>],
        env: &mut RoundEnv,
    ) -> Vec<f32> {
        if env.uplink.is_aggregate() {
            return self.round_aggregate(t, honest_grads, byz_grads, env);
        }
        let d = env.d;
        let n = env.n_total();
        debug_assert_eq!(self.estimates.len(), n);

        if let Some(ps) = env.payloads {
            // Wire payloads (tcp): each worker tracked its own estimate
            // copy remotely and shipped either the dense init gradient or
            // a masked difference; the server-side estimates advance
            // through the same `dasha_apply` law, staying in bit-exact
            // lockstep with the worker copies.
            for (widx, p) in ps.iter().enumerate() {
                env.meter
                    .record_uplink_sized(widx, payload_uplink_len(p));
                match p {
                    Payload::Dense { values } => {
                        debug_assert!(!self.initialized || env.k == d);
                        self.estimates[widx].copy_from_slice(values);
                    }
                    Payload::Sparse {
                        values,
                        mask: Some(mw),
                    } => {
                        dasha_apply(
                            &mut self.estimates[widx],
                            &mw.to_mask(),
                            values,
                        );
                    }
                    other => debug_assert!(
                        false,
                        "dasha expects dense or masked-difference \
                         payloads, got {other:?}"
                    ),
                }
            }
            self.initialized = true;
            let refs: Vec<&[f32]> =
                self.estimates.iter().map(|m| m.as_slice()).collect();
            return env.aggregator.aggregate_vec(&refs);
        }

        let byz = byzantine_vectors(t, honest_grads, byz_grads, env);
        let rk = RandK { d, k: env.k };

        // target vectors per worker: what each worker wants its estimate
        // to track this round.
        let update_worker =
            |this: &mut Self, widx: usize, target: &[f32], env: &mut RoundEnv| {
                if !this.initialized || env.k == d {
                    // dense init round (or no compression at all)
                    this.estimates[widx].copy_from_slice(target);
                    this.meter_dense(env, widx);
                    return;
                }
                // c = C_i(target - est); est += c (unbiased RandK)
                for (df, (tv, ev)) in this.diff.iter_mut().zip(
                    target.iter().zip(this.estimates[widx].iter()),
                ) {
                    *df = tv - ev;
                }
                let mut wrng = env.rng.derive(TAG_DASHA, t, widx as u64);
                let mask = rk.draw(&mut wrng);
                mask.compress_into(&this.diff, &mut this.payload);
                let payload_len = this.payload.len();
                this.meter_sparse(env, widx, payload_len);
                // est += a · α · scatter(payload) — DASHA's h-update law
                // with the stabilization stepsize a = 1/(2ω + 1); see
                // `payload::dasha_gain`. One shared function advances the
                // coordinator's estimates and every remote worker's local
                // copy, keeping them in bit-exact lockstep over the wire.
                dasha_apply(&mut this.estimates[widx], &mask, &this.payload);
            };

        for (i, g) in honest_grads.iter().enumerate() {
            update_worker(self, i, g, env);
        }
        for (j, v) in byz.iter().enumerate() {
            update_worker(self, env.n_honest + j, v, env);
        }
        self.initialized = true;

        let refs: Vec<&[f32]> =
            self.estimates.iter().map(|m| m.as_slice()).collect();
        env.aggregator.aggregate_vec(&refs)
    }

    fn momenta(&self) -> Option<&[Vec<f32>]> {
        if self.estimates.is_empty() {
            None // sum mode keeps only S = Σᵢ ĝᵢ, not the rows
        } else {
            Some(&self.estimates)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_env::Env;
    use super::*;
    use crate::tensor;

    #[test]
    fn first_round_is_dense_and_exact() {
        let mut env = Env::new(64, 4, 0, 8);
        let grads = env.constant_grads(3.0);
        let mut alg = ByzDashaPage::new(64, 4);
        let r = alg.round(0, &grads, &[], &mut env.env());
        for v in &r {
            assert!((v - 3.0).abs() < 1e-6);
        }
        // dense uplink: 4 workers * (12 + 4 + 64*4)
        assert_eq!(env.meter.uplink, 4 * (12 + 4 + 256));
    }

    #[test]
    fn estimates_track_changing_gradients() {
        // gradient drifts slowly; estimates must follow within noise.
        let d = 128;
        let mut env = Env::new(d, 3, 0, 32);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let mut alg = ByzDashaPage::new(d, 3);
        let mut g = vec![1.0f32; d];
        alg.round(0, &vec![g.clone(); 3], &[], &mut env.env());
        for t in 1..200u64 {
            for v in g.iter_mut() {
                *v *= 0.99;
            }
            alg.round(t, &vec![g.clone(); 3], &[], &mut env.env());
        }
        let est = &alg.estimates[0];
        let err = tensor::dist_sq(est, &g).sqrt() / tensor::norm(&g);
        assert!(err < 0.5, "relative tracking error {err}");
    }

    #[test]
    fn stationary_gradients_give_exact_estimates_in_expectation() {
        // constant g: diff -> 0 once estimate hits g; estimates converge.
        let d = 32;
        let mut env = Env::new(d, 2, 0, 8);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let g: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).cos()).collect();
        let grads = vec![g.clone(); 2];
        let mut alg = ByzDashaPage::new(d, 2);
        for t in 0..100 {
            alg.round(t, &grads, &[], &mut env.env());
        }
        let err = tensor::dist_sq(&alg.estimates[0], &g);
        assert!(err < 1e-8, "residual {err}");
    }

    #[test]
    fn aggregate_counters_pin_one_rebuild_then_incrementals() {
        use crate::transport::uplink::ReducePlan;
        let d = 64;
        let plan = ReducePlan::new(2, &[true; 3]);
        let mut env = Env::new(d, 3, 0, 8);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let grads = env.constant_grads(2.0);
        let mut alg = ByzDashaPage::new_aggregate(d);
        for t in 0..6 {
            alg.round(t, &grads, &[], &mut env.env_agg(&plan, false));
        }
        assert_eq!(alg.agg_counters(), (1, 5));

        // k = d never leaves the dense path: every round rebuilds
        let mut env = Env::new(d, 3, 0, d);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let grads = env.constant_grads(2.0);
        let mut alg = ByzDashaPage::new_aggregate(d);
        for t in 0..4 {
            alg.round(t, &grads, &[], &mut env.env_agg(&plan, false));
        }
        assert_eq!(alg.agg_counters(), (4, 0));
    }

    #[test]
    fn aggregate_first_round_is_dense_and_exact() {
        use crate::transport::uplink::{
            agg_body_len, agg_dense_payload_len, ReducePlan,
        };
        let d = 64;
        let plan = ReducePlan::new(2, &[true; 4]);
        let mut env = Env::new(d, 4, 0, 8);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let grads = env.constant_grads(3.0);
        let mut alg = ByzDashaPage::new_aggregate(d);
        let r = alg.round(0, &grads, &[], &mut env.env_agg(&plan, false));
        for v in &r {
            assert!((v - 3.0).abs() < 1e-6);
        }
        // flat model: four singleton AGG frames, all coordinator ingress
        let want = 4 * agg_body_len(1, agg_dense_payload_len(d)) as u64;
        assert_eq!(env.meter.uplink, want);
        assert_eq!(env.meter.coordinator_ingress, want);
    }

    #[test]
    fn aggregate_tree_model_splits_ingress_from_relayed() {
        use crate::transport::uplink::{
            agg_body_len, agg_dense_payload_len, ReducePlan,
        };
        let d = 64;
        // n = 3, b = 2: roots {0, 1}, slot 2 relays through slot 0
        let plan = ReducePlan::new(2, &[true; 3]);
        let mut env = Env::new(d, 3, 0, 8);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let grads = env.constant_grads(1.0);
        let mut alg = ByzDashaPage::new_aggregate(d);
        alg.round(0, &grads, &[], &mut env.env_agg(&plan, true));
        let p = agg_dense_payload_len(d);
        let ingress = (agg_body_len(2, p) + agg_body_len(1, p)) as u64;
        let relayed = agg_body_len(1, p) as u64;
        assert_eq!(env.meter.coordinator_ingress, ingress);
        assert_eq!(env.meter.uplink, ingress + relayed);
    }

    #[test]
    fn aggregate_tracks_forward_mean() {
        use crate::transport::uplink::ReducePlan;
        // the same drifting-gradient run through the value-forwarding
        // path (mean of n estimate rows) and the sum mode (S/n): equal
        // up to f32 summation order.
        let d = 32;
        let n = 3;
        let plan = ReducePlan::new(2, &[true; 3]);
        let mut fwd_env = Env::new(d, n, 0, 8);
        fwd_env.aggregator =
            crate::aggregators::parse_spec("mean", 0).unwrap();
        let mut agg_env = Env::new(d, n, 0, 8);
        agg_env.aggregator =
            crate::aggregators::parse_spec("mean", 0).unwrap();
        let mut fwd = ByzDashaPage::new(d, n);
        let mut agg = ByzDashaPage::new_aggregate(d);
        let mut g: Vec<f32> = (0..d).map(|i| (i as f32 * 0.4).sin()).collect();
        for t in 0..40u64 {
            for v in g.iter_mut() {
                *v *= 0.98;
            }
            let grads = vec![g.clone(); n];
            let a = fwd.round(t, &grads, &[], &mut fwd_env.env());
            let b =
                agg.round(t, &grads, &[], &mut agg_env.env_agg(&plan, false));
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "round {t}: {x} vs {y}");
            }
        }
        // the oracle's lazily simulated worker copies match the
        // value-forwarding server rows bit for bit (same masks, same law)
        let rows = agg.momenta().expect("local oracle allocates copies");
        assert_eq!(rows, fwd.momenta().unwrap());
    }

    #[test]
    fn sparse_rounds_cost_less_than_dense() {
        let d = 11_809;
        let mut env = Env::new(d, 10, 0, 118);
        let grads = env.constant_grads(1.0);
        let mut alg = ByzDashaPage::new(d, 10);
        alg.round(0, &grads, &[], &mut env.env());
        let dense_cost = env.meter.uplink;
        alg.round(1, &grads, &[], &mut env.env());
        let sparse_cost = env.meter.uplink - dense_cost;
        assert!(
            (sparse_cost as f64) < 0.03 * dense_cost as f64,
            "sparse {sparse_cost} vs dense {dense_cost}"
        );
    }
}
