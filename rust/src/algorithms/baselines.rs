//! The three Table-1 reference points:
//!
//! * [`RobustDgd`] — SOTA *without compression* [3]: dense gradients,
//!   server-side per-worker momentum, robust aggregation.
//! * [`DgdRandK`] — SOTA *without robustness* [1, 33]: per-worker RandK,
//!   plain averaging, no momentum.
//! * [`Dgd`] — vanilla distributed gradient descent.

use super::{byzantine_vectors, Algorithm, RoundEnv, UplinkCtx};
use crate::compression::codec::mask_wire_len;
use crate::compression::payload::{Payload, TAG_DGD_RANDK};
use crate::compression::RandK;
use crate::tensor;
use crate::transport::uplink::{
    agg_dense_payload_len, combine_slot_values, AggValue,
};
use crate::transport::{
    compressed_grad_len, full_grad_len, payload_uplink_len,
};

/// Shared dense sum-mode round body (`uplink = "aggregate"`): meter the
/// modeled `AGG` traffic, then either consume the transport's pre-folded
/// total (tcp) or fold the in-process gradient rows through the same
/// plan recursion (the local oracle — bit-identical to the wire fold by
/// construction). Returns the covered sum Σg (zeros when nothing was
/// covered). Uncovered slots contribute nothing, exactly like the zero
/// rows a silent slot leaves in the value-forwarded gradient store.
fn dense_aggregate_sum(
    uplink: &mut UplinkCtx<'_>,
    honest_grads: &[Vec<f32>],
    byz_grads: &[Vec<f32>],
    d: usize,
    n_honest: usize,
    meter: &mut crate::transport::ByteMeter,
) -> Vec<f32> {
    let (plan, wire, physical_tree) = uplink.take_parts();
    crate::transport::uplink::meter_model(plan, physical_tree, meter, |_| {
        agg_dense_payload_len(d)
    });
    let total = match wire {
        Some(total) => total,
        None => combine_slot_values(plan, |s| {
            let w = s as usize;
            Some(AggValue::Dense(if w < n_honest {
                honest_grads[w].clone()
            } else {
                byz_grads[w - n_honest].clone()
            }))
        }),
    };
    match total {
        Some(AggValue::Dense(v)) if v.len() == d => v,
        _ => vec![0.0; d],
    }
}

/// Robust distributed GD with Polyak momentum (no compression).
pub struct RobustDgd {
    momenta: Vec<Vec<f32>>,
    /// `uplink = "aggregate"`: the summed momentum M = Σᵢ mᵢ. The dense
    /// per-worker law commutes with summation (mᵢ ← β·mᵢ + (1−β)·gᵢ ⇒
    /// M ← β·M + (1−β)·Σgᵢ), so the aggregate path advances one
    /// d-vector where value-forwarding keeps n rows; R^t = M/n under
    /// the `aggregator = "mean"` the mode's validation pins. Empty on
    /// the value-forwarding path.
    agg_momentum: Vec<f32>,
}

impl RobustDgd {
    pub fn new(d: usize, n_workers: usize) -> Self {
        RobustDgd {
            momenta: vec![vec![0.0; d]; n_workers],
            agg_momentum: Vec::new(),
        }
    }

    /// Sum-mode constructor (`uplink = "aggregate"`): no per-worker
    /// momentum rows are ever allocated — only their running sum.
    pub fn new_aggregate(d: usize) -> Self {
        RobustDgd {
            momenta: Vec::new(),
            agg_momentum: vec![0.0; d],
        }
    }

    fn round_aggregate(
        &mut self,
        honest_grads: &[Vec<f32>],
        byz_grads: &[Vec<f32>],
        env: &mut RoundEnv,
    ) -> Vec<f32> {
        let sum = dense_aggregate_sum(
            &mut env.uplink,
            honest_grads,
            byz_grads,
            env.d,
            env.n_honest,
            env.meter,
        );
        tensor::scale_add(
            &mut self.agg_momentum,
            env.beta,
            1.0 - env.beta,
            &sum,
        );
        let mut out = self.agg_momentum.clone();
        tensor::scale(&mut out, 1.0 / env.n_total() as f32);
        out
    }
}

impl Algorithm for RobustDgd {
    fn name(&self) -> &'static str {
        "robust-dgd"
    }

    fn round(
        &mut self,
        t: u64,
        honest_grads: &[Vec<f32>],
        byz_grads: &[Vec<f32>],
        env: &mut RoundEnv,
    ) -> Vec<f32> {
        if env.uplink.is_aggregate() {
            return self.round_aggregate(honest_grads, byz_grads, env);
        }
        let byz = byzantine_vectors(t, honest_grads, byz_grads, env);
        let apply = |this: &mut Self, widx: usize, g: &[f32], env: &mut RoundEnv| {
            env.meter.record_uplink_sized(widx, full_grad_len(env.d));
            tensor::scale_add(&mut this.momenta[widx], env.beta, 1.0 - env.beta, g);
        };
        for (i, g) in honest_grads.iter().enumerate() {
            apply(self, i, g, env);
        }
        for (j, g) in byz.iter().enumerate() {
            apply(self, env.n_honest + j, g, env);
        }
        let refs: Vec<&[f32]> =
            self.momenta.iter().map(|m| m.as_slice()).collect();
        env.aggregator.aggregate_vec(&refs)
    }

    fn momenta(&self) -> Option<&[Vec<f32>]> {
        if self.momenta.is_empty() {
            None // sum mode keeps only Σmᵢ, not the per-worker rows
        } else {
            Some(&self.momenta)
        }
    }
}

/// DGD + local RandK, plain mean (the no-robustness compression SOTA).
pub struct DgdRandK;

impl DgdRandK {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        DgdRandK
    }
}

impl Algorithm for DgdRandK {
    fn name(&self) -> &'static str {
        "dgd-randk"
    }

    fn round(
        &mut self,
        t: u64,
        honest_grads: &[Vec<f32>],
        byz_grads: &[Vec<f32>],
        env: &mut RoundEnv,
    ) -> Vec<f32> {
        let d = env.d;

        if let Some(ps) = env.payloads {
            // Wire payloads (tcp, SparseLocal plan — at k = d the plan
            // is Dense and the oracle path below runs instead): masks
            // were drawn remotely from the same derived streams, so the
            // scatter here reproduces the in-process round bit for bit.
            let mut sum = vec![0f32; d];
            for (widx, p) in ps.iter().enumerate() {
                env.meter
                    .record_uplink_sized(widx, payload_uplink_len(p));
                match p {
                    Payload::Sparse {
                        values,
                        mask: Some(mw),
                    } => {
                        let mask = mw.to_mask();
                        let a = mask.alpha();
                        for (&ci, &v) in mask.idx.iter().zip(values) {
                            sum[ci as usize] += a * v;
                        }
                    }
                    other => debug_assert!(
                        false,
                        "dgd-randk expects masked sparse payloads, \
                         got {other:?}"
                    ),
                }
            }
            tensor::scale(&mut sum, 1.0 / ps.len() as f32);
            return sum;
        }

        let byz = byzantine_vectors(t, honest_grads, byz_grads, env);
        let rk = RandK { d, k: env.k };
        let mut sum = vec![0f32; d];
        let mut count = 0usize;
        let mut payload: Vec<f32> = Vec::with_capacity(env.k);
        // Sparse-domain accumulation (§Perf): scatter α·payload straight
        // into the running sum instead of densifying each reconstruction —
        // bit-identical to reconstruct_into + axpy, without the O(d)
        // zero-fill and read per worker.
        let mut add = |widx: usize,
                       g: &[f32],
                       sum: &mut Vec<f32>,
                       env: &mut RoundEnv| {
            let mut wrng = env.rng.derive(TAG_DGD_RANDK, t, widx as u64);
            let mask = rk.draw(&mut wrng);
            mask.compress_into(g, &mut payload);
            let mask_bytes = if env.k < d { mask_wire_len(d, env.k) } else { 0 };
            env.meter.record_uplink_sized(
                widx,
                compressed_grad_len(payload.len(), mask_bytes),
            );
            let a = mask.alpha();
            for (&ci, &v) in mask.idx.iter().zip(&payload) {
                sum[ci as usize] += a * v;
            }
        };
        for (i, g) in honest_grads.iter().enumerate() {
            add(i, g, &mut sum, env);
            count += 1;
        }
        for (j, g) in byz.iter().enumerate() {
            add(env.n_honest + j, g, &mut sum, env);
            count += 1;
        }
        tensor::scale(&mut sum, 1.0 / count as f32);
        sum
    }
}

/// Vanilla distributed GD: dense, mean, no momentum.
pub struct Dgd;

impl Dgd {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Dgd
    }
}

impl Algorithm for Dgd {
    fn name(&self) -> &'static str {
        "dgd"
    }

    fn round(
        &mut self,
        t: u64,
        honest_grads: &[Vec<f32>],
        byz_grads: &[Vec<f32>],
        env: &mut RoundEnv,
    ) -> Vec<f32> {
        let n = env.n_total();
        if env.uplink.is_aggregate() {
            let mut sum = dense_aggregate_sum(
                &mut env.uplink,
                honest_grads,
                byz_grads,
                env.d,
                env.n_honest,
                env.meter,
            );
            tensor::scale(&mut sum, 1.0 / n as f32);
            return sum;
        }
        let byz = byzantine_vectors(t, honest_grads, byz_grads, env);
        let mut all: Vec<&[f32]> = Vec::with_capacity(n);
        for g in honest_grads {
            all.push(g);
        }
        for g in &byz {
            all.push(g);
        }
        for (widx, _) in all.iter().enumerate() {
            env.meter.record_uplink_sized(widx, full_grad_len(env.d));
        }
        tensor::mean(&all)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_env::Env;
    use super::*;

    #[test]
    fn dgd_is_exact_mean() {
        let mut env = Env::new(8, 4, 0, 8);
        let mut grads = env.constant_grads(1.0);
        grads[0] = vec![5.0; 8];
        let r = Dgd::new().round(0, &grads, &[], &mut env.env());
        for v in &r {
            assert!((v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn dgd_randk_unbiased_mean() {
        let d = 64;
        let mut env = Env::new(d, 8, 0, 16);
        let grads = env.constant_grads(1.0);
        let mut alg = DgdRandK::new();
        let mut acc = vec![0f64; d];
        let rounds = 2000;
        for t in 0..rounds {
            let r = alg.round(t, &grads, &[], &mut env.env());
            for (a, v) in acc.iter_mut().zip(&r) {
                *a += *v as f64;
            }
        }
        for a in &acc {
            let mean = a / rounds as f64;
            assert!((mean - 1.0).abs() < 0.1, "{mean}");
        }
    }

    #[test]
    fn robust_dgd_filters_alie_with_momentum() {
        let d = 12;
        let mut env = Env::new(d, 10, 3, d);
        env.attack = crate::attacks::parse_spec("alie:8").unwrap();
        env.aggregator =
            crate::aggregators::parse_spec("nnm+cwtm", 3).unwrap();
        env.beta = 0.9;
        let grads = env.constant_grads(1.0);
        let mut alg = RobustDgd::new(d, 13);
        let mut r = Vec::new();
        for t in 0..50 {
            r = alg.round(t, &grads, &[], &mut env.env());
        }
        // after warmup, update direction should be near the honest grad
        assert!((r[0] - 1.0).abs() < 0.3, "{}", r[0]);
    }

    #[test]
    fn robust_dgd_uplink_is_dense() {
        let d = 100;
        let mut env = Env::new(d, 2, 0, 10);
        let grads = env.constant_grads(1.0);
        let mut alg = RobustDgd::new(d, 2);
        alg.round(0, &grads, &[], &mut env.env());
        assert_eq!(env.meter.uplink, 2 * (12 + 4 + 400));
    }

    #[test]
    fn dgd_aggregate_is_exact_mean_with_modeled_bytes() {
        use crate::transport::uplink::{
            agg_body_len, agg_dense_payload_len, ReducePlan,
        };
        let d = 8;
        let plan = ReducePlan::new(2, &[true; 4]);
        let mut env = Env::new(d, 4, 0, d);
        let mut grads = env.constant_grads(1.0);
        grads[0] = vec![5.0; d];
        let r =
            Dgd::new().round(0, &grads, &[], &mut env.env_agg(&plan, false));
        for v in &r {
            assert!((v - 2.0).abs() < 1e-6);
        }
        // flat model: four singleton AGG frames, all coordinator ingress
        let want = 4 * agg_body_len(1, agg_dense_payload_len(d)) as u64;
        assert_eq!(env.meter.uplink, want);
        assert_eq!(env.meter.coordinator_ingress, want);
    }

    #[test]
    fn robust_dgd_aggregate_tracks_forward_mean() {
        use crate::transport::uplink::ReducePlan;
        // the same run through value-forwarding (mean over n momentum
        // rows) and the sum mode (M/n): equal up to f32 summation order.
        let d = 16;
        let n = 5;
        let plan = ReducePlan::new(2, &[true; 5]);
        let mut fwd_env = Env::new(d, n, 0, d);
        fwd_env.aggregator =
            crate::aggregators::parse_spec("mean", 0).unwrap();
        let mut agg_env = Env::new(d, n, 0, d);
        agg_env.aggregator =
            crate::aggregators::parse_spec("mean", 0).unwrap();
        let mut fwd = RobustDgd::new(d, n);
        let mut agg = RobustDgd::new_aggregate(d);
        for t in 0..30u64 {
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|w| {
                    (0..d)
                        .map(|i| ((t as f32 + w as f32) * 0.1 + i as f32).sin())
                        .collect()
                })
                .collect();
            let a = fwd.round(t, &grads, &[], &mut fwd_env.env());
            let b =
                agg.round(t, &grads, &[], &mut agg_env.env_agg(&plan, false));
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "round {t}: {x} vs {y}");
            }
        }
        assert!(agg.momenta().is_none(), "sum mode keeps no rows");
    }

    #[test]
    fn dgd_randk_at_k_eq_d_ships_no_mask() {
        let d = 50;
        let mut env = Env::new(d, 2, 0, d);
        let grads = env.constant_grads(1.0);
        let mut alg = DgdRandK::new();
        alg.round(0, &grads, &[], &mut env.env());
        // payload d floats, no mask wire: header + len + 4d
        assert_eq!(env.meter.uplink, 2 * (12 + 4 + 4 * 50));
    }
}
