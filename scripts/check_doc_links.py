#!/usr/bin/env python3
"""Fail CI on broken intra-repo links in the markdown docs.

Scans docs/*.md plus the READMEs for markdown links, resolves every
relative target against the linking file's directory, and exits 1
listing each target that does not exist in the repo. For links into
other markdown files with a #fragment, the fragment is checked against
the target's headings (GitHub slug rules). External links (http/https/
mailto) are ignored — this checker guards repo-internal consistency,
not the internet.

No third-party dependencies; run from anywhere inside the repo:

    python3 scripts/check_doc_links.py
"""

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — skip images' leading ! handled by the same pattern,
# and tolerate titles: [t](path "title")
LINK_RE = re.compile(r"\[[^\]]*\]\(\s*([^)\s]+)(?:\s+\"[^\"]*\")?\s*\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading):
    """GitHub's anchor algorithm: lowercase, drop punctuation, dash-join."""
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path):
    with open(path, encoding="utf-8") as f:
        body = FENCE_RE.sub("", f.read())
    return {slugify(h) for h in HEADING_RE.findall(body)}


def check_file(path):
    problems = []
    with open(path, encoding="utf-8") as f:
        body = FENCE_RE.sub("", f.read())
    for target in LINK_RE.findall(body):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target_path, _, fragment = target.partition("#")
        if not target_path:  # same-file anchor
            if fragment and fragment not in anchors_of(path):
                problems.append(f"{target} (no such heading)")
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target_path)
        )
        if not os.path.exists(resolved):
            problems.append(f"{target} -> {os.path.relpath(resolved, REPO)}")
            continue
        if fragment and resolved.endswith(".md"):
            if fragment not in anchors_of(resolved):
                problems.append(f"{target} (no such heading)")
    return problems


def main():
    files = sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    for readme in ("README.md", os.path.join("rust", "README.md")):
        p = os.path.join(REPO, readme)
        if os.path.exists(p):
            files.append(p)
    if not files:
        print("check_doc_links: no markdown files found", file=sys.stderr)
        return 1
    broken = 0
    for path in files:
        for problem in check_file(path):
            rel = os.path.relpath(path, REPO)
            print(f"BROKEN  {rel}: {problem}", file=sys.stderr)
            broken += 1
    checked = ", ".join(os.path.relpath(p, REPO) for p in files)
    if broken:
        print(f"check_doc_links: {broken} broken link(s)", file=sys.stderr)
        return 1
    print(f"check_doc_links: OK ({checked})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
