//! TopK — the canonical *biased* compressor, included as the Appendix-C /
//! related-work baseline (RoSDHB-Local "lends itself to both biased and
//! unbiased schemes", §3.3).
//!
//! TopK keeps the k largest-magnitude coordinates. It is **not** unbiased,
//! so it must not be combined with the d/k unbiasing factor; reconstruction
//! scatters the raw values.

use super::Mask;

#[derive(Clone, Debug)]
pub struct TopK {
    pub d: usize,
    pub k: usize,
}

impl TopK {
    pub fn from_frac(d: usize, k_frac: f64) -> Self {
        let k = ((d as f64 * k_frac).round() as usize).clamp(1, d);
        TopK { d, k }
    }

    /// Mask of the k largest |g_i| (ties broken by lower index, so the
    /// result is deterministic).
    pub fn mask_for(&self, g: &[f32]) -> Mask {
        assert_eq!(g.len(), self.d);
        let mut order: Vec<u32> = (0..self.d as u32).collect();
        order.sort_by(|&a, &b| {
            let ma = g[a as usize].abs();
            let mb = g[b as usize].abs();
            mb.partial_cmp(&ma).unwrap().then(a.cmp(&b))
        });
        Mask::new(self.d, order[..self.k].to_vec())
    }

    /// Biased reconstruction: scatter without scaling.
    pub fn reconstruct(&self, mask: &Mask, values: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.d];
        for (&i, &v) in mask.idx.iter().zip(values) {
            out[i as usize] = v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;

    #[test]
    fn keeps_largest_magnitudes() {
        let g = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let tk = TopK { d: 5, k: 2 };
        let m = tk.mask_for(&g);
        assert_eq!(m.idx, vec![1, 3]);
        let rec = tk.reconstruct(&m, &m.compress(&g));
        assert_eq!(rec, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn topk_is_best_k_term_approximation() {
        let g: Vec<f32> = (0..64).map(|i| ((i * 37 % 64) as f32) - 32.0).collect();
        let tk = TopK { d: 64, k: 8 };
        let m = tk.mask_for(&g);
        let rec = tk.reconstruct(&m, &m.compress(&g));
        let err_top = tensor::dist_sq(&rec, &g);
        // any other 8-subset has error >= topk's
        let m2 = Mask::new(64, (0..8).collect());
        let rec2 = tk.reconstruct(&m2, &m2.compress(&g));
        assert!(err_top <= tensor::dist_sq(&rec2, &g));
    }

    #[test]
    fn deterministic_ties() {
        let g = vec![1.0; 6];
        let tk = TopK { d: 6, k: 3 };
        assert_eq!(tk.mask_for(&g).idx, vec![0, 1, 2]);
    }
}
