//! How a round's gradients are exchanged — in-process or over sockets.
//!
//! [`Trainer::step`][super::Trainer::step] drives a [`RoundTransport`]:
//! given the current parameters, fill `grad_store`/`loss_store` with this
//! round's per-worker contributions. Two implementations exist:
//!
//! * [`LocalTransport`] — the persistent worker pool (or the sequential
//!   engine under PJRT). This is the tested oracle: every other transport
//!   must reproduce its `RunReport` bit for bit on the same config/seed.
//! * [`TcpTransport`] — the coordinator side of the socket runtime
//!   (`transport = "tcp"`): broadcast the model through
//!   [`CoordinatorServer`], collect worker uplinks in wire format, and
//!   hand the typed payloads to the algorithm layer.
//!
//! ## Wire plans and byte parity
//!
//! The simulation's [`ByteMeter`][crate::transport::ByteMeter] *models*
//! per-round traffic; the TCP path must *transmit* exactly those bytes.
//! Which payload travels is the [`PayloadPlan`] implied by the config —
//! the same enum the worker-side
//! [`CompressorState`][crate::compression::CompressorState] derives, so
//! coordinator expectations and worker uplinks can never disagree:
//!
//! * [`PayloadPlan::SparseGlobal`] (RoSDHB, k < d) — `ModelBroadcast`
//!   (+mask seed) down; k-value sparse payloads up, no mask on the wire
//!   (both ends re-derive it from the seed).
//! * [`PayloadPlan::SparseLocal`] (rosdhb-local, dgd-randk, rosdhb-u
//!   with randk) — plain broadcast down; k values **plus** the worker's
//!   own [`MaskWire`] up.
//! * [`PayloadPlan::Quantized`] (rosdhb-u with qsgd) — plain broadcast
//!   down; one bit-packed QSGD block up.
//! * [`PayloadPlan::DashaDiff`] (byz-dasha-page, k < d) — dense init
//!   uplink in round 1, masked difference payloads after.
//! * [`PayloadPlan::Dense`] (robust-dgd, dgd, and any k = d config) —
//!   plain broadcast down, dense payloads up; these are decoded straight
//!   into `grad_store` and the algorithm runs its oracle path.
//!
//! Under every non-dense plan the validated [`Payload`]s are delivered to
//! the algorithm through [`RoundTransport::round_payloads`] /
//! [`RoundEnv::payloads`][crate::algorithms::RoundEnv]; because workers
//! derive their compression randomness from the same per-(round, worker)
//! streams as the in-process simulation (see
//! [`crate::prng::round_stream`]), the run stays bit-identical to the
//! local transport while the compressor state lives on the client, where
//! the paper places it.
//!
//! Payload-attack Byzantine workers join as *drones*: the omniscient
//! adversary of the paper is still simulated server-side (that is what
//! keeps runs reproducible), but each drone receives the broadcast and
//! ships a correctly-sized placeholder uplink so measured socket traffic
//! matches the accounting model. Crafting needs the dense honest inputs,
//! so payload attacks over TCP are limited at config validation to the
//! shared-mask and dense plans. Crash-fault Byzantine workers
//! (`attack = "none"`, f > 0) stay silent, exactly like the simulation.
//!
//! A worker that misses the round deadline, crashes, or violates the
//! protocol degrades into a dropped contribution (a zero payload of the
//! plan's exact shape, zero loss, eviction from later rounds) — never a
//! hang.

use crate::checkpoint::SlotMembership;
use crate::compression::codec::MaskWire;
use crate::compression::payload::{Payload, PayloadPlan};
use crate::compression::RandK;
use crate::config::{ChurnEvent, ExperimentConfig};
use crate::telemetry::{Event, Histogram, Telemetry};
use crate::transport::downlink::FanoutPlan;
use crate::transport::evloop::ServerIo;
use crate::transport::monitor::SlotHealth;
use crate::transport::net::{
    AggEvent, CoordinatorServer, NetStats, COLLECT_GRACE,
};
use crate::transport::uplink::{combine, AggFrame, AggValue, ReducePlan};
use crate::transport::WireMessage;
use crate::worker::{GradEngine, HonestWorker};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::pool::{Job, WorkerPool};

/// How long a coordinator waits for all workers to join.
pub const RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(300);

/// Pull a worker out of its slot, or report a poisoned transport: slots
/// are only left empty when the pool died mid-round and took the
/// in-flight workers with it. Returning `Err` here keeps the "failures
/// surface as `Err`, never an abort" contract even on calls *after* such
/// a failure.
fn take_worker(
    workers: &mut [Option<HonestWorker>],
    slot: usize,
) -> Result<HonestWorker> {
    workers[slot].take().ok_or_else(|| {
        anyhow!(
            "trainer poisoned: worker {slot} was lost in a failed round \
             (worker pool died); rebuild the Trainer"
        )
    })
}

/// The contribution of a slot with no worker behind it: an exact zero
/// gradient and zero loss — momentum decays, sums gain nothing. Both
/// transports substitute the identical values, which is what keeps a
/// churned run on sockets bit-equal to the local oracle.
fn zero_slot(grad: &mut Vec<f32>, loss: &mut f32, d: usize) {
    grad.resize(d, 0.0);
    grad.fill(0.0);
    *loss = 0.0;
}

/// A checkpointed membership record that constrains nothing: every slot
/// active, no pending leave. Only such records are accepted across a
/// slot-count mismatch (a checkpoint written by the *other* transport —
/// the counts differ when Byzantine slots are simulated server-side).
fn membership_is_all_active(m: &[SlotMembership]) -> bool {
    m.iter().all(|s| s.active && !s.pending_left)
}

/// Observer-only snapshot of a socket transport's health, consumed by
/// the status endpoint ([`crate::telemetry::status`]). `None` from
/// transports that move no real bytes.
#[derive(Clone, Debug)]
pub struct TransportHealth {
    /// Per-slot membership + RTT/jitter estimates.
    pub slots: Vec<SlotHealth>,
    /// Measured socket counters.
    pub net: NetStats,
    /// `RESYNC` frames the coordinator absorbed (telemetry-only — not
    /// part of [`NetStats`], which is checkpoint-serialized).
    pub relay_resyncs: u64,
    /// Workers dropped from later rounds (deadline misses, broken
    /// connections, DASHA state divergence).
    pub evictions: u64,
}

/// One round-trip of the synchronous round loop: distribute `params`,
/// collect per-worker gradient contributions.
pub trait RoundTransport: Send {
    fn name(&self) -> &'static str;

    /// Fill `grad_store[w]` / `loss_store[w]` for every gradient slot
    /// (honest workers first, then data-level Byzantine workers). `engine`
    /// is the trainer's sequential gradient engine — used only by the
    /// local transport when no pool is available (PJRT).
    ///
    /// `downlink` overrides the broadcast message under `downlink =
    /// "delta"`: the trainer's [`DownlinkCodec`][crate::transport::downlink::DownlinkCodec]
    /// frame describing the previous round's aggregate. `None` = the
    /// transport builds its default model broadcast. The local transport
    /// ignores it (workers are fed parameters in-process).
    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &mut self,
        t: u64,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        downlink: Option<&WireMessage>,
        grad_store: &mut [Vec<f32>],
        loss_store: &mut [f32],
    ) -> Result<()>;

    /// Fresh honest full-d gradients at `params` for (G,B) estimation —
    /// requires direct worker access, so only the local transport can.
    fn probe_honest(
        &mut self,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        n_honest: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// The typed uplink payloads of the last [`Self::exchange`], one per
    /// gradient slot, when this transport received them in wire form
    /// (TCP under a non-dense [`PayloadPlan`]). `None` for the local
    /// transport — algorithms then run the identical compression
    /// themselves from the dense gradients.
    fn round_payloads(&self) -> Option<&[Payload]> {
        None
    }

    /// The fully reduced uplink of the last [`Self::exchange`] under
    /// `uplink = "aggregate"` (TCP only): the sum of every covered
    /// slot's contribution, folded in the [`ReducePlan`]'s fixed
    /// association. `None` whenever the transport forwards per-worker
    /// values — the algorithm then reduces them itself through the same
    /// plan, which is what keeps the two paths bit-identical.
    fn take_aggregated(&mut self) -> Option<AggValue> {
        None
    }

    /// Per-gradient-slot activity flags (`true` = a worker currently
    /// owns the slot and is expected to contribute) — the trainer
    /// builds each round's [`ReducePlan`] from them, so both transports
    /// must report membership identically.
    fn active_gradient_slots(&self) -> Vec<bool>;

    /// Measured socket traffic, if this transport moves real bytes.
    fn net_stats(&self) -> Option<NetStats> {
        None
    }

    /// Process the *opening* boundary of `epoch`: vacate slots whose
    /// workers announced a graceful leave or are churned out by the
    /// coordinator's schedule, re-fill `+` churn slots (TCP: through a
    /// re-opened rendezvous window), and re-admit deadline-suspended
    /// workers under `config: readmit = "next-epoch"`. Returns the
    /// sorted, deduplicated gradient slots whose **membership** changed —
    /// the trainer resets their per-slot algorithm state. Re-admissions
    /// are fault recovery, not membership changes, and are not reported.
    fn epoch_boundary(
        &mut self,
        epoch: u64,
        churn: &[ChurnEvent],
        cfg: &ExperimentConfig,
    ) -> Result<Vec<usize>> {
        let _ = (epoch, churn, cfg);
        Ok(Vec::new())
    }

    /// Per-slot membership flags for checkpointing (local: one entry per
    /// gradient slot; TCP: one per connection slot). A checkpoint
    /// carries them so a restored run resumes with the same slots vacant
    /// — whether the vacancy came from the churn schedule or a graceful
    /// `LEAVE` — instead of silently re-activating them.
    fn membership(&self) -> Vec<SlotMembership>;

    /// Apply checkpointed membership to this transport (the restore side
    /// of [`Self::membership`]). Transports must tolerate a checkpoint
    /// written by the *other* transport when it records no vacancy (the
    /// slot counts differ across transports for server-simulated
    /// Byzantine slots, but an all-active checkpoint constrains
    /// nothing); any vacancy with a mismatched slot count is an error.
    fn restore_membership(&mut self, m: &[SlotMembership]) -> Result<()>;

    /// Pre-seed measured wire counters from a checkpoint so end-of-run
    /// socket accounting stays cumulative across a restore. No-op for
    /// transports that move no real bytes.
    fn preseed_net_stats(&mut self, _stats: NetStats) {}

    /// Release transport resources (TCP: send `BYE` to all workers).
    /// Also runs on drop; explicit calls make shutdown ordering testable.
    fn shutdown(&mut self) {}

    /// Diagnostic/test hook into the in-process implementation.
    fn as_local_mut(&mut self) -> Option<&mut LocalTransport> {
        None
    }

    /// The transport's event journal (created from `config: trace_path`
    /// at rendezvous). Disabled — a dead handle every emit site skips —
    /// for transports without one, and whenever tracing is off.
    fn telemetry(&self) -> Telemetry {
        Telemetry::disabled()
    }

    /// `(broadcast, collect)` wall-clock split of the last
    /// [`Self::exchange`], consumed once by the trainer's phase
    /// histograms. `None` when the transport does not separate the
    /// phases (the local pool computes and collects in one step — the
    /// trainer then books the whole exchange under `collect`).
    fn take_phase_durations(&mut self) -> Option<(Duration, Duration)> {
        None
    }

    /// Live health snapshot for the status endpoint; `None` for
    /// transports that move no real bytes.
    fn health(&self) -> Option<TransportHealth> {
        None
    }

    /// Per-worker uplink round-trip histograms (deterministic
    /// power-of-two buckets, nondeterministic counts — excluded from
    /// every parity comparison). `None` when the transport measures no
    /// real round-trips.
    fn worker_latency(&self) -> Option<&[Histogram]> {
        None
    }
}

// ------------------------------------------------------------------ local

/// In-process gradient exchange over the persistent [`WorkerPool`] (the
/// pre-socket behavior of `Trainer`, unchanged results).
pub struct LocalTransport {
    /// Gradient workers: honest in slots `[0, n_honest)`, then data-level
    /// Byzantine workers. `None` only while a worker is in flight inside
    /// the pool.
    pub(crate) workers: Vec<Option<HonestWorker>>,
    /// Persistent gradient pool (native engine only; `None` under PJRT —
    /// sequential there, identical numerics).
    pub(crate) pool: Option<WorkerPool>,
    /// Broadcast parameter buffer shared with pool threads; refreshed in
    /// place each round (no allocation once every job handle is returned).
    shared_params: Arc<Vec<f32>>,
    /// Slot membership under churn: a vacated slot contributes an exact
    /// zero gradient and zero loss (the same substitution the TCP path
    /// makes for a vacant connection) until a `+` churn event re-fills
    /// it — the oracle the socket runtime must reproduce bit for bit.
    active: Vec<bool>,
}

impl LocalTransport {
    pub fn new(workers: Vec<HonestWorker>, pool: Option<WorkerPool>) -> Self {
        let n = workers.len();
        LocalTransport {
            workers: workers.into_iter().map(Some).collect(),
            pool,
            shared_params: Arc::new(Vec::new()),
            active: vec![true; n],
        }
    }

    pub fn n_slots(&self) -> usize {
        self.workers.len()
    }
}

impl RoundTransport for LocalTransport {
    fn name(&self) -> &'static str {
        "local"
    }

    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &mut self,
        _t: u64,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        _downlink: Option<&WireMessage>,
        grad_store: &mut [Vec<f32>],
        loss_store: &mut [f32],
    ) -> Result<()> {
        let n_grad = self.workers.len();
        debug_assert_eq!(grad_store.len(), n_grad);
        if let Some(pool) = &self.pool {
            // Refresh the shared broadcast buffer in place; all job
            // handles from the previous round have been returned, so the
            // Arc is unique and this is a copy, not an allocation. (A
            // non-unique Arc can only mean a previous round failed midway
            // and leaked a handle — fall back to a fresh buffer then.)
            if Arc::get_mut(&mut self.shared_params).is_none() {
                self.shared_params = Arc::new(Vec::new());
            }
            let buf = Arc::get_mut(&mut self.shared_params)
                .expect("freshly replaced Arc is unique");
            buf.resize(params.len(), 0.0);
            buf.copy_from_slice(params);
            let mut n_jobs = 0usize;
            for slot in 0..n_grad {
                if !self.active[slot] {
                    zero_slot(&mut grad_store[slot], &mut loss_store[slot], params.len());
                    continue;
                }
                let worker = take_worker(&mut self.workers, slot)?;
                let buf = std::mem::take(&mut grad_store[slot]);
                pool.submit(Job {
                    slot,
                    worker,
                    params: Arc::clone(&self.shared_params),
                    batch,
                    buf,
                })?;
                n_jobs += 1;
            }
            let mut first_err: Option<anyhow::Error> = None;
            for _ in 0..n_jobs {
                let done = pool.recv()?;
                self.workers[done.slot] = Some(done.worker);
                grad_store[done.slot] = done.buf;
                match done.loss {
                    Ok(l) => loss_store[done.slot] = l,
                    Err(e) => {
                        if first_err.is_none() {
                            first_err =
                                Some(anyhow!("worker {}: {e}", done.slot));
                        }
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
        } else {
            for slot in 0..n_grad {
                if !self.active[slot] {
                    zero_slot(&mut grad_store[slot], &mut loss_store[slot], params.len());
                    continue;
                }
                let mut worker = take_worker(&mut self.workers, slot)?;
                let res = worker.compute_grad_into(
                    engine,
                    params,
                    batch,
                    &mut grad_store[slot],
                );
                self.workers[slot] = Some(worker);
                loss_store[slot] = res?;
            }
        }
        Ok(())
    }

    fn epoch_boundary(
        &mut self,
        epoch: u64,
        churn: &[ChurnEvent],
        cfg: &ExperimentConfig,
    ) -> Result<Vec<usize>> {
        // Re-derive every worker from (seed, epoch, membership) alone —
        // the same rebuild a remote `rosdhb join` process runs, so a
        // worker arriving mid-run reconstructs identical state no matter
        // when (or in which order) it joined.
        let rebuilt = super::build_training_workers_for_epoch(cfg, epoch)?.0;
        self.workers = rebuilt.into_iter().map(Some).collect();
        let mut changed = Vec::new();
        for ev in churn.iter().filter(|ev| ev.epoch == epoch) {
            if ev.slot < self.active.len() {
                self.active[ev.slot] = ev.join;
                changed.push(ev.slot);
            }
        }
        changed.sort_unstable();
        changed.dedup();
        Ok(changed)
    }

    fn active_gradient_slots(&self) -> Vec<bool> {
        self.active.clone()
    }

    fn membership(&self) -> Vec<SlotMembership> {
        self.active
            .iter()
            .map(|&a| SlotMembership {
                active: a,
                pending_left: false,
            })
            .collect()
    }

    fn restore_membership(&mut self, m: &[SlotMembership]) -> Result<()> {
        if m.len() != self.active.len() {
            if membership_is_all_active(m) {
                return Ok(());
            }
            return Err(anyhow!(
                "checkpoint membership covers {} slots, the local \
                 transport has {}",
                m.len(),
                self.active.len()
            ));
        }
        if m.iter().any(|s| s.pending_left) {
            return Err(anyhow!(
                "checkpoint carries a pending graceful leave — only the \
                 tcp transport can honor it at the next epoch boundary"
            ));
        }
        for (slot, s) in m.iter().enumerate() {
            self.active[slot] = s.active;
        }
        Ok(())
    }

    fn probe_honest(
        &mut self,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        n_honest: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(n_honest);
        for slot in 0..n_honest {
            let mut worker = take_worker(&mut self.workers, slot)?;
            let mut buf = vec![0f32; params.len()];
            let res = worker.compute_grad_into(engine, params, batch, &mut buf);
            self.workers[slot] = Some(worker);
            res?;
            out.push(buf);
        }
        Ok(out)
    }

    fn as_local_mut(&mut self) -> Option<&mut LocalTransport> {
        Some(self)
    }
}

// -------------------------------------------------------------------- tcp

/// Membership state of one connected slot across epochs. Orthogonal to
/// the server-side *liveness* of the connection: a slot can be `Active`
/// with a suspended (deadline-missing) socket behind it — that is a
/// fault, handled by `config: readmit`, not a membership change.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotState {
    /// A worker owns the slot and is expected to contribute.
    Active,
    /// The worker left (gracefully or by churn schedule): the slot
    /// contributes exact zeros, silently, until a `+` churn event
    /// re-fills it from a re-opened rendezvous window.
    Vacant,
}

/// Coordinator side of `transport = "tcp"`.
pub struct TcpTransport {
    server: ServerIo,
    plan: PayloadPlan,
    d: usize,
    seed: u64,
    /// Gradient slots (honest + data-level Byzantine) — mirrors the
    /// trainer's `grad_store` layout.
    n_grad: usize,
    /// Payload-attack drones reply with placeholder uplinks; crash-fault
    /// Byzantine slots stay silent.
    drones_reply: bool,
    timeout: Duration,
    /// This round's validated uplink payloads, one per gradient slot —
    /// filled by [`Self::exchange`] under every non-dense plan and handed
    /// to the algorithm via [`RoundTransport::round_payloads`].
    payloads: Vec<Payload>,
    /// Per-connection membership state (one entry per joined socket).
    slots: Vec<SlotState>,
    /// Slots whose latest uplink carried a `LEAVE` announcement — they
    /// vacate at the next epoch boundary.
    pending_left: Vec<bool>,
    /// Config fingerprint re-checked against mid-run joiners.
    fingerprint: u64,
    /// `config: readmit = "next-epoch"`: deadline-suspended workers whose
    /// socket survived are woken at epoch boundaries.
    readmit_next_epoch: bool,
    /// The run's fan-out plan, kept for epoch-boundary re-plans: the
    /// event-loop server re-derives relay placement from its RTT
    /// monitor after every membership change (the threaded server keeps
    /// join-order placement — it is the placement oracle).
    fanout: FanoutPlan,
    /// Event journal from `config: trace_path` (disabled when empty);
    /// the server runtimes hold clones of the same handle.
    telemetry: Telemetry,
    /// `(broadcast, collect)` wall-clock split of the last exchange,
    /// taken once per round by the trainer's phase histograms.
    last_phase: Option<(Duration, Duration)>,
    /// Per-worker uplink round-trip histograms (telemetry-only).
    worker_hist: Vec<Histogram>,
    /// Workers dropped from later rounds so far.
    evictions: u64,
    /// `uplink = "aggregate"`: workers ship `AGG` frames (relays fold
    /// them), dedicated readers own the receive side, and the exchange
    /// runs [`Self::exchange_aggregate`] instead of per-worker collect.
    uplink_agg: bool,
    /// `config: branching` — the reduction tree's arity (aggregate
    /// mode builds a [`ReducePlan`] from it every round).
    branching: usize,
    /// The last aggregate exchange's full reduction, taken once by the
    /// trainer ([`RoundTransport::take_aggregated`]).
    aggregated: Option<AggValue>,
}

impl TcpTransport {
    /// Wait for all `n_total` workers to join `server`, then build the
    /// transport. `d` is the model dimension of the trainer's engine.
    pub fn rendezvous(
        server: CoordinatorServer,
        cfg: &ExperimentConfig,
        d: usize,
    ) -> Result<Self> {
        Self::rendezvous_inner(server.into(), cfg, d, None)
    }

    /// [`Self::rendezvous`] over either socket runtime (`config: io`).
    pub fn rendezvous_io(
        server: ServerIo,
        cfg: &ExperimentConfig,
        d: usize,
    ) -> Result<Self> {
        Self::rendezvous_inner(server, cfg, d, None)
    }

    /// Rendezvous for a run restoring from a checkpoint: wait only for
    /// the slots `membership` holds active (vacated slots stay vacant,
    /// exactly as the checkpointing run left them) and seed the slot
    /// states from the record. Worker ids are assigned to the active
    /// slots in arrival order — every joiner re-derives its shard and
    /// RNG streams from the `WELCOME`d id alone, so join order can never
    /// change results.
    pub fn rendezvous_restored(
        server: CoordinatorServer,
        cfg: &ExperimentConfig,
        d: usize,
        membership: &[SlotMembership],
    ) -> Result<Self> {
        Self::rendezvous_inner(server.into(), cfg, d, Some(membership))
    }

    /// [`Self::rendezvous_restored`] over either socket runtime.
    pub fn rendezvous_restored_io(
        server: ServerIo,
        cfg: &ExperimentConfig,
        d: usize,
        membership: &[SlotMembership],
    ) -> Result<Self> {
        Self::rendezvous_inner(server, cfg, d, Some(membership))
    }

    fn rendezvous_inner(
        mut server: ServerIo,
        cfg: &ExperimentConfig,
        d: usize,
        membership: Option<&[SlotMembership]>,
    ) -> Result<Self> {
        let attack =
            crate::attacks::parse_spec(&cfg.attack).map_err(|e| anyhow!(e))?;
        let (n_grad, drones_reply) = match attack {
            crate::attacks::AttackKind::LabelFlip => (cfg.n_total(), false),
            crate::attacks::AttackKind::None => (cfg.n_honest, false),
            crate::attacks::AttackKind::Payload(_) => (cfg.n_honest, true),
        };
        let n = cfg.n_total();
        // Journal from the first admission on: the runtimes clone the
        // handle, so rendezvous events (admissions, rejections) land in
        // the same file as the round trace.
        let telemetry = Telemetry::to_path(&cfg.trace_path)
            .map_err(|e| anyhow!("trace_path {:?}: {e}", cfg.trace_path))?;
        server.set_telemetry(telemetry.clone());
        let uplink_agg = cfg.uplink == "aggregate";
        if uplink_agg {
            // the threaded runtime spawns its per-connection uplink
            // readers at admission, so this must precede rendezvous
            server.enable_uplink_readers();
        }
        let (active, pending_left): (Vec<bool>, Vec<bool>) = match membership
        {
            Some(m) if m.len() == n => m
                .iter()
                .map(|s| (s.active, s.pending_left))
                .unzip(),
            Some(m) if !membership_is_all_active(m) => {
                return Err(anyhow!(
                    "checkpoint membership covers {} slots, this run has \
                     {n} connection slots",
                    m.len()
                ))
            }
            _ => (vec![true; n], vec![false; n]),
        };
        if active.iter().all(|&a| a) {
            server.rendezvous(n, cfg.wire_fingerprint(), RENDEZVOUS_TIMEOUT)?;
        } else {
            let open: Vec<usize> = (0..n).filter(|&i| active[i]).collect();
            eprintln!(
                "rosdhb[tcp]: restored membership has {} vacant slot(s) — \
                 waiting for {} workers",
                n - open.len(),
                open.len()
            );
            server.rendezvous_slots(
                n,
                &open,
                cfg.wire_fingerprint(),
                RENDEZVOUS_TIMEOUT,
            )?;
        }
        let fanout = FanoutPlan::parse(&cfg.fanout, cfg.branching)
            .map_err(|e| anyhow!(e))?;
        if let FanoutPlan::Tree { .. } = fanout {
            // interior tree positions should reply to the coordinator
            // (RESYNC recovery reads their socket): gradient slots and
            // drones qualify, crash-fault-silent and vacant slots become
            // leaves
            let can_relay: Vec<bool> = (0..n)
                .map(|i| (i < n_grad || drones_reply) && active[i])
                .collect();
            server.apply_fanout(&fanout, &can_relay)?;
        }
        Ok(TcpTransport {
            server,
            plan: PayloadPlan::from_config(cfg, d),
            d,
            seed: cfg.seed,
            n_grad,
            drones_reply,
            timeout: Duration::from_millis(cfg.round_timeout_ms.max(1)),
            payloads: Vec::new(),
            slots: active
                .iter()
                .map(|&a| if a { SlotState::Active } else { SlotState::Vacant })
                .collect(),
            pending_left,
            fingerprint: cfg.wire_fingerprint(),
            readmit_next_epoch: cfg.readmit == "next-epoch",
            fanout,
            telemetry,
            last_phase: None,
            worker_hist: vec![Histogram::default(); n],
            evictions: 0,
            uplink_agg,
            branching: cfg.branching,
            aggregated: None,
        })
    }

    /// Whether the plan hands typed payloads to the algorithm layer
    /// (every plan except `Dense`, whose uplinks *are* the gradients and
    /// go straight into `grad_store`).
    fn delivers_payloads(&self) -> bool {
        self.plan != PayloadPlan::Dense
    }

    /// Validate one decoded uplink against the wire plan and extract its
    /// payload. Anything malformed — wrong round, wrong kind, wrong
    /// sizes, a mask that is not a sorted k-subset of [0, d) — is an
    /// `Err` (a dropped contribution), never a panic downstream.
    fn accept_uplink(&self, t: u64, msg: WireMessage) -> Result<Payload> {
        let WireMessage::Grad { round, payload, .. } = msg else {
            return Err(anyhow!("unexpected uplink message: {msg:?}"));
        };
        if round != t {
            return Err(anyhow!("round {round} != current {t}"));
        }
        match (self.plan, &payload) {
            (
                PayloadPlan::SparseGlobal { k },
                Payload::Sparse { values, mask: None },
            ) => {
                if values.len() != k {
                    return Err(anyhow!(
                        "payload {} values != k {k}",
                        values.len()
                    ));
                }
            }
            (
                PayloadPlan::SparseLocal { k },
                Payload::Sparse {
                    values,
                    mask: Some(mw),
                },
            ) => {
                check_wire_mask(mw, k, self.d)?;
                if values.len() != k {
                    return Err(anyhow!(
                        "payload {} values != k {k}",
                        values.len()
                    ));
                }
            }
            (PayloadPlan::Quantized { s }, Payload::Quantized(b)) => {
                // block dimension is already pinned to d by the decoder
                if b.s != s {
                    return Err(anyhow!(
                        "quantized payload has s={}, plan says s={s}",
                        b.s
                    ));
                }
            }
            (PayloadPlan::DashaDiff { .. }, Payload::Dense { values }) => {
                if t != 1 {
                    return Err(anyhow!(
                        "dense dasha uplink outside the init round"
                    ));
                }
                if values.len() != self.d {
                    return Err(anyhow!(
                        "dense init has {} values, model has {}",
                        values.len(),
                        self.d
                    ));
                }
            }
            (
                PayloadPlan::DashaDiff { k },
                Payload::Sparse {
                    values,
                    mask: Some(mw),
                },
            ) => {
                if t == 1 {
                    return Err(anyhow!(
                        "masked dasha difference in the dense init round"
                    ));
                }
                check_wire_mask(mw, k, self.d)?;
                if values.len() != k {
                    return Err(anyhow!(
                        "payload {} values != k {k}",
                        values.len()
                    ));
                }
            }
            (PayloadPlan::Dense, Payload::Dense { values }) => {
                if values.len() != self.d {
                    return Err(anyhow!(
                        "dense gradient has {} values, model has {}",
                        values.len(),
                        self.d
                    ));
                }
            }
            (plan, p) => {
                return Err(anyhow!(
                    "{} payload does not fit wire plan {plan:?}",
                    p.kind_name()
                ))
            }
        }
        Ok(payload)
    }

    /// A zero payload of the plan's exact shape — what a dropped
    /// contribution degrades into (momentum decays, DASHA estimates hold,
    /// sums gain nothing; byte metering stays size-true). Shares the one
    /// constructor with the worker-side drone placeholder.
    fn zero_payload(&self, t: u64) -> Payload {
        self.plan.zero_payload(self.d, t <= 1)
    }

    /// `active_gradient_slots` without the trait indirection.
    fn gradient_slot_activity(&self) -> Vec<bool> {
        (0..self.n_grad)
            .map(|w| self.slots[w] == SlotState::Active)
            .collect()
    }

    /// Validate one accumulated-uplink frame body against the round and
    /// the model shape. Anything malformed is a dropped frame — never a
    /// panic downstream of the decode.
    fn accept_agg(&self, t: u64, body: &[u8]) -> Result<AggFrame> {
        let frame = AggFrame::decode_body(body)
            .map_err(|e| anyhow!("undecodable AGG frame: {e}"))?;
        if frame.round != t {
            return Err(anyhow!("round {} != current {t}", frame.round));
        }
        match &frame.value {
            AggValue::Dense(v) => {
                if v.len() != self.d {
                    return Err(anyhow!(
                        "accumulated dense value has {} entries, model \
                         has {}",
                        v.len(),
                        self.d
                    ));
                }
            }
            AggValue::Sparse { idx, val } => {
                if idx.len() != val.len() {
                    return Err(anyhow!(
                        "sparse accumulation has {} indices but {} values",
                        idx.len(),
                        val.len()
                    ));
                }
                if !idx.windows(2).all(|w| w[0] < w[1]) {
                    return Err(anyhow!(
                        "sparse accumulation indices not strictly sorted"
                    ));
                }
                if idx.last().is_some_and(|&i| i as usize >= self.d) {
                    return Err(anyhow!(
                        "sparse accumulation index beyond model \
                         dimension {}",
                        self.d
                    ));
                }
            }
        }
        if let Some(&s) =
            frame.slots.iter().find(|&&s| s as usize >= self.n_grad)
        {
            return Err(anyhow!(
                "accumulated frame covers slot {s}, run has {} gradient \
                 slots",
                self.n_grad
            ));
        }
        Ok(frame)
    }

    /// The `uplink = "aggregate"` exchange: every broadcast carries
    /// `expect_reply = false` (dedicated uplink readers own the receive
    /// side), the collect loop drains [`AggEvent`]s until every active
    /// gradient slot is covered, and the arrived frames — fully folded
    /// subtrees under `fanout = "tree"`, singletons under flat or a
    /// degraded tree, or any mix — are re-nested through the round's
    /// [`ReducePlan`]. That recursion is the same association the
    /// relays and the local oracle use, which is what makes the total
    /// bit-identical across physical topologies.
    fn exchange_aggregate(
        &mut self,
        t: u64,
        msg: &WireMessage,
        loss_store: &mut [f32],
    ) -> Result<()> {
        self.aggregated = None;
        let n_conn = self.server.n_workers();
        let expect = vec![false; n_conn];
        let phase_start = Instant::now();
        self.server.broadcast(t, msg, &expect, self.timeout);
        let broadcast_elapsed = phase_start.elapsed();
        if self.server.n_alive() == 0 {
            return Err(anyhow!(
                "all {n_conn} workers are gone — nothing left to train with"
            ));
        }
        let active = self.gradient_slot_activity();
        let plan = ReducePlan::new(self.branching, &active);
        let want = active.iter().filter(|a| **a).count();
        let mut covered = vec![false; self.n_grad];
        let mut n_covered = 0usize;
        let mut frames: Vec<AggFrame> = Vec::new();
        let collect_start = Instant::now();
        let deadline = collect_start + self.timeout + COLLECT_GRACE;
        while n_covered < want {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let Some(ev) = self.server.poll_agg(deadline - now) else {
                continue; // poll timed out — the deadline check exits
            };
            match ev {
                AggEvent::Frame { worker, body } => {
                    match self.accept_agg(t, &body) {
                        Ok(frame) => {
                            for &s in &frame.slots {
                                if !std::mem::replace(
                                    &mut covered[s as usize],
                                    true,
                                ) {
                                    n_covered += 1;
                                }
                            }
                            frames.push(frame);
                        }
                        Err(e) => eprintln!(
                            "rosdhb[tcp]: round {t}: worker {worker}: {e} \
                             — accumulated frame dropped"
                        ),
                    }
                }
                AggEvent::Leave { worker } => {
                    if let Some(pl) =
                        self.pending_left.get_mut(worker as usize)
                    {
                        *pl = true;
                    }
                }
                AggEvent::Resync { worker } => {
                    eprintln!(
                        "rosdhb[tcp]: round {t}: worker {worker} lost its \
                         relay feed — re-delivering this round directly"
                    );
                    self.server.redeliver_direct(
                        worker as usize,
                        t,
                        msg,
                        self.timeout,
                    );
                }
                AggEvent::Down { worker, reason } => {
                    let w = worker as usize;
                    eprintln!("rosdhb[tcp]: round {t}: worker {w}: {reason}");
                    self.server.evict(w);
                    self.evictions += 1;
                    self.telemetry.emit(|| Event::WorkerEvicted {
                        round: t,
                        worker: w,
                        reason: reason.clone(),
                    });
                    self.telemetry.dump_flight_recorder("worker eviction");
                }
            }
        }
        self.last_phase = Some((broadcast_elapsed, collect_start.elapsed()));
        let combined = combine(&plan, frames);
        if combined.dropped > 0 {
            eprintln!(
                "rosdhb[tcp]: round {t}: {} duplicate or unplaceable \
                 accumulated frame(s) dropped",
                combined.dropped
            );
        }
        for &(slot, loss) in &combined.losses {
            if let Some(l) = loss_store.get_mut(slot as usize) {
                *l = loss;
            }
        }
        // A slot the reduction never covered contributed nothing this
        // round: its loss reads zero and the sum simply gains nothing —
        // the identical outcome to the forward path's zero payload.
        // DASHA stays stateful on the client, so a missed contribution
        // permanently offsets the worker's local estimate from the
        // server sum: evict, exactly like the forward path.
        let mut is_covered = vec![false; self.n_grad];
        for &s in &combined.covered {
            is_covered[s as usize] = true;
        }
        for (w, &a) in active.iter().enumerate() {
            if is_covered[w] {
                continue;
            }
            loss_store[w] = 0.0;
            if !a {
                continue; // vacant slot: the expected membership state
            }
            let note = if matches!(self.plan, PayloadPlan::DashaDiff { .. })
            {
                self.server.evict(w);
                self.evictions += 1;
                self.telemetry.emit(|| Event::WorkerEvicted {
                    round: t,
                    worker: w,
                    reason: "client-side estimate diverged".into(),
                });
                self.telemetry.dump_flight_recorder("worker eviction");
                " (evicted: client-side estimate diverged)"
            } else {
                ""
            };
            eprintln!(
                "rosdhb[tcp]: round {t}: worker {w} contributed nothing \
                 to the reduction — zero contribution assumed{note}"
            );
        }
        self.aggregated = combined.total;
        Ok(())
    }
}

/// A shipped mask must be a strictly sorted k-subset of [0, d) in the
/// modeled wire size, or the contribution is dropped — `to_mask` (and
/// every scatter after it) must never see anything else.
fn check_wire_mask(mw: &MaskWire, k: usize, d: usize) -> Result<()> {
    match mw {
        MaskWire::IndexList { d: wd, idx } => {
            if *wd != d {
                return Err(anyhow!("mask dimension {wd} != model {d}"));
            }
            if idx.len() != k {
                return Err(anyhow!("mask has {} indices, want {k}", idx.len()));
            }
            if !idx.windows(2).all(|w| w[0] < w[1]) {
                return Err(anyhow!("mask indices not strictly sorted"));
            }
            if let Some(&last) = idx.last() {
                if last as usize >= d {
                    return Err(anyhow!("mask index {last} >= d {d}"));
                }
            }
        }
        MaskWire::Bitset { d: wd, bits } => {
            if *wd != d || bits.len() != d.div_ceil(8) {
                return Err(anyhow!(
                    "mask bitset sized {} for d {wd}, want {} for d {d}",
                    bits.len(),
                    d.div_ceil(8)
                ));
            }
            let mut count = 0usize;
            for (byte_i, &b) in bits.iter().enumerate() {
                for bit in 0..8 {
                    if b & (1 << bit) != 0 {
                        if byte_i * 8 + bit >= d {
                            return Err(anyhow!("mask bit set beyond d {d}"));
                        }
                        count += 1;
                    }
                }
            }
            if count != k {
                return Err(anyhow!("mask has {count} bits set, want {k}"));
            }
        }
    }
    Ok(())
}

impl RoundTransport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &mut self,
        t: u64,
        _engine: &mut dyn GradEngine,
        params: &[f32],
        _batch: usize,
        downlink: Option<&WireMessage>,
        grad_store: &mut [Vec<f32>],
        loss_store: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(grad_store.len(), self.n_grad);
        // downlink = "delta": the trainer's codec frame (the previous
        // round's aggregate) replaces the model broadcast — workers step
        // their local replica instead of receiving θ.
        let own_msg;
        let msg: &WireMessage = match downlink {
            Some(m) => m,
            None => {
                own_msg = match self.plan {
                    PayloadPlan::SparseGlobal { .. } => {
                        WireMessage::ModelBroadcast {
                            round: t,
                            params: params.to_vec(),
                            mask_seed: RandK::round_seed(self.seed, t),
                        }
                    }
                    _ => WireMessage::ModelBroadcastPlain {
                        round: t,
                        params: params.to_vec(),
                    },
                };
                &own_msg
            }
        };
        if self.uplink_agg {
            // grad_store stays untouched: the algorithm layer consumes
            // the full reduction via `take_aggregated`, never the
            // per-slot gradients
            return self.exchange_aggregate(t, msg, loss_store);
        }
        let n_conn = self.server.n_workers();
        let mut expect = vec![false; n_conn];
        for (w, e) in expect.iter_mut().enumerate().take(self.n_grad) {
            *e = self.slots[w] == SlotState::Active;
        }
        if self.drones_reply {
            for (w, e) in expect.iter_mut().enumerate().skip(self.n_grad) {
                *e = self.slots[w] == SlotState::Active;
            }
        }
        let phase_start = Instant::now();
        let n_expected = self.server.broadcast(t, msg, &expect, self.timeout);
        let broadcast_elapsed = phase_start.elapsed();
        if self.server.n_alive() == 0 {
            return Err(anyhow!(
                "all {n_conn} workers are gone — nothing left to train with"
            ));
        }
        let deliver = self.delivers_payloads();
        if deliver && self.payloads.len() != self.n_grad {
            self.payloads =
                vec![Payload::Dense { values: Vec::new() }; self.n_grad];
        }
        let mut got = vec![false; self.n_grad];
        let collect_start = Instant::now();
        for reply in self.server.collect(n_expected, t, self.timeout) {
            let w = reply.worker as usize;
            // telemetry-only: fold the runtime's round-trip stamp into
            // this worker's latency histogram
            if let Some(lat) = reply.latency {
                if let Some(h) = self.worker_hist.get_mut(w) {
                    h.record(lat);
                }
            }
            if reply.left {
                // Graceful goodbye: this uplink still counts, the slot
                // vacates at the next epoch boundary.
                self.pending_left[w] = true;
            }
            match reply.result {
                Ok((loss, bytes)) => {
                    if w >= self.n_grad {
                        continue; // drone placeholder: metered, ignored
                    }
                    let outcome = WireMessage::decode(&bytes, self.d)
                        .map_err(|e| anyhow!("undecodable uplink: {e}"))
                        .and_then(|msg| self.accept_uplink(t, msg));
                    match outcome {
                        Ok(payload) => {
                            if deliver {
                                self.payloads[w] = payload;
                            } else {
                                // Dense plan: the payload *is* the
                                // gradient the algorithm consumes.
                                let Payload::Dense { values } = payload
                                else {
                                    unreachable!("accept_uplink checked kind")
                                };
                                grad_store[w].clear();
                                grad_store[w].extend_from_slice(&values);
                            }
                            loss_store[w] = loss;
                            got[w] = true;
                        }
                        Err(e) => eprintln!(
                            "rosdhb[tcp]: round {t}: worker {w}: {e} — \
                             contribution dropped"
                        ),
                    }
                }
                Err(e) => {
                    eprintln!("rosdhb[tcp]: round {t}: worker {w}: {e}");
                    // an errored reply drops the worker from this
                    // round and (deadline misses aside, which may be
                    // readmitted) from later ones — journal it and
                    // dump the flight recorder so the rounds leading
                    // up to the failure are visible post-mortem
                    self.evictions += 1;
                    self.telemetry.emit(|| Event::WorkerEvicted {
                        round: t,
                        worker: w,
                        reason: e.clone(),
                    });
                    self.telemetry.dump_flight_recorder("worker eviction");
                }
            }
        }
        self.last_phase = Some((broadcast_elapsed, collect_start.elapsed()));
        // Stalled / crashed / malformed workers degrade into a zero
        // contribution for this round (and eviction for later ones when
        // the connection is gone) — the run keeps moving.
        for (w, ok) in got.iter().enumerate() {
            if !*ok {
                let substitute = if deliver {
                    let zp = self.zero_payload(t);
                    let kind = zp.kind_name();
                    self.payloads[w] = zp;
                    kind
                } else {
                    let g = &mut grad_store[w];
                    g.resize(self.d, 0.0);
                    g.fill(0.0);
                    "gradient"
                };
                loss_store[w] = 0.0;
                // A vacant slot contributing zeros is the *expected*
                // membership state (the local oracle substitutes the
                // same values) — not a fault worth a warning or a DASHA
                // eviction.
                if self.slots[w] == SlotState::Vacant {
                    continue;
                }
                // DASHA is stateful on the client: the worker already
                // advanced its local estimate when it compressed this
                // round's difference, while the zero substitute froze the
                // server copy — the two are permanently offset, so every
                // later difference from this worker would be silently
                // biased. Evict it (estimate row freezes: crash-fault
                // semantics). Stateless plans just lose one round.
                let note =
                    if matches!(self.plan, PayloadPlan::DashaDiff { .. }) {
                        self.server.evict(w);
                        self.evictions += 1;
                        self.telemetry.emit(|| Event::WorkerEvicted {
                            round: t,
                            worker: w,
                            reason: "client-side estimate diverged".into(),
                        });
                        self.telemetry
                            .dump_flight_recorder("worker eviction");
                        " (evicted: client-side estimate diverged)"
                    } else {
                        ""
                    };
                eprintln!(
                    "rosdhb[tcp]: round {t}: worker {w} contributed nothing — \
                     zero {substitute} substituted{note}"
                );
            }
        }
        Ok(())
    }

    fn probe_honest(
        &mut self,
        _engine: &mut dyn GradEngine,
        _params: &[f32],
        _batch: usize,
        _n_honest: usize,
    ) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!(
            "(G,B) probing needs direct worker access — run it under \
             transport = \"local\""
        ))
    }

    fn round_payloads(&self) -> Option<&[Payload]> {
        if self.delivers_payloads() && self.payloads.len() == self.n_grad {
            Some(&self.payloads)
        } else {
            None
        }
    }

    fn take_aggregated(&mut self) -> Option<AggValue> {
        self.aggregated.take()
    }

    fn active_gradient_slots(&self) -> Vec<bool> {
        self.gradient_slot_activity()
    }

    fn epoch_boundary(
        &mut self,
        epoch: u64,
        churn: &[ChurnEvent],
        _cfg: &ExperimentConfig,
    ) -> Result<Vec<usize>> {
        let mut changed = Vec::new();
        // Graceful leaves announced by LEAVE frames during the closing
        // epoch: send BYE, let the io thread exit, vacate the slot.
        for w in 0..self.slots.len() {
            if std::mem::take(&mut self.pending_left[w])
                && self.slots[w] == SlotState::Active
            {
                self.server.detach(w);
                self.slots[w] = SlotState::Vacant;
                changed.push(w);
            }
        }
        // Coordinator-scheduled churn: forced leaves first, then joins
        // into the vacated slots through a re-opened rendezvous window.
        // Every scheduled event reports its slot as changed whether or
        // not the state flipped — the local oracle counts identically,
        // which is what keeps the two `changed` sets (and therefore the
        // per-slot state resets) bit-equal.
        let mut joins: Vec<usize> = Vec::new();
        for ev in churn.iter().filter(|ev| ev.epoch == epoch) {
            if ev.slot >= self.slots.len() {
                continue;
            }
            if ev.join {
                if self.slots[ev.slot] == SlotState::Vacant {
                    joins.push(ev.slot);
                }
            } else if self.slots[ev.slot] == SlotState::Active {
                self.server.detach(ev.slot);
                self.slots[ev.slot] = SlotState::Vacant;
            }
            changed.push(ev.slot);
        }
        if !joins.is_empty() {
            self.server.reopen_rendezvous(
                &joins,
                self.fingerprint,
                RENDEZVOUS_TIMEOUT,
            )?;
            for &s in &joins {
                self.slots[s] = SlotState::Active;
            }
        }
        // Deadline-suspended sockets wake up under readmit = "next-epoch".
        // Fault recovery, not membership: their momenta were never reset,
        // so they are deliberately absent from `changed`.
        if self.readmit_next_epoch {
            for w in 0..self.slots.len() {
                if self.slots[w] == SlotState::Active
                    && !self.server.is_alive(w)
                    && self.server.readmit(w)
                {
                    eprintln!(
                        "rosdhb[tcp]: epoch {epoch}: worker {w} re-admitted \
                         after suspension"
                    );
                }
            }
        }
        // Membership settled — let the monitor re-derive relay placement
        // from observed RTT/jitter (event-loop runtime only; the
        // threaded server keeps join-order placement and stays the
        // oracle). Same capability rule as at rendezvous. Aggregate
        // uplinks pin the placement for the whole run instead: the
        // physical fold order must keep matching the logical
        // [`ReducePlan`], and join-order placement is exactly that.
        if !self.uplink_agg {
            let can_relay: Vec<bool> = (0..self.slots.len())
                .map(|w| {
                    (w < self.n_grad || self.drones_reply)
                        && self.slots[w] == SlotState::Active
                })
                .collect();
            self.server.boundary_replan(&self.fanout, &can_relay)?;
        }
        changed.sort_unstable();
        changed.dedup();
        Ok(changed)
    }

    fn membership(&self) -> Vec<SlotMembership> {
        self.slots
            .iter()
            .zip(&self.pending_left)
            .map(|(&state, &pl)| SlotMembership {
                active: state == SlotState::Active,
                pending_left: pl,
            })
            .collect()
    }

    fn restore_membership(&mut self, m: &[SlotMembership]) -> Result<()> {
        if m.len() != self.slots.len() {
            if membership_is_all_active(m) {
                return Ok(());
            }
            return Err(anyhow!(
                "checkpoint membership covers {} slots, this run has {} \
                 connection slots",
                m.len(),
                self.slots.len()
            ));
        }
        for (w, s) in m.iter().enumerate() {
            match (self.slots[w], s.active) {
                (SlotState::Active, false) => {
                    // a worker joined a slot the checkpoint holds vacant
                    // (full rendezvous before the restore was seen):
                    // release it — the slot stays vacant until a `+`
                    // churn event re-fills it
                    eprintln!(
                        "rosdhb[tcp]: restore: slot {w} is vacant in the \
                         checkpoint — releasing its joined worker"
                    );
                    self.server.detach(w);
                    self.slots[w] = SlotState::Vacant;
                }
                (SlotState::Vacant, true) => {
                    return Err(anyhow!(
                        "checkpoint holds slot {w} active but no worker \
                         joined it — rendezvous the active slots first \
                         (TcpTransport::rendezvous_restored)"
                    ))
                }
                _ => {}
            }
            self.pending_left[w] = s.pending_left;
        }
        Ok(())
    }

    fn preseed_net_stats(&mut self, stats: NetStats) {
        self.server.preseed_stats(stats);
    }

    fn net_stats(&self) -> Option<NetStats> {
        Some(self.server.stats())
    }

    fn shutdown(&mut self) {
        self.server.shutdown();
    }

    fn telemetry(&self) -> Telemetry {
        self.telemetry.clone()
    }

    fn take_phase_durations(&mut self) -> Option<(Duration, Duration)> {
        self.last_phase.take()
    }

    fn health(&self) -> Option<TransportHealth> {
        Some(TransportHealth {
            slots: self.server.slot_health(),
            net: self.server.stats(),
            relay_resyncs: self.server.relay_resyncs(),
            evictions: self.evictions,
        })
    }

    fn worker_latency(&self) -> Option<&[Histogram]> {
        Some(&self.worker_hist)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::Mask;

    #[test]
    fn wire_mask_check_rejects_malformed_shapes() {
        let d = 64;
        let mask = Mask::new(d, vec![1, 5, 9]);
        let ok = MaskWire::choose(&mask);
        check_wire_mask(&ok, 3, d).unwrap();
        // wrong k
        assert!(check_wire_mask(&ok, 4, d).is_err());
        // unsorted / duplicate indices
        let bad = MaskWire::IndexList {
            d,
            idx: vec![5, 5, 9],
        };
        assert!(check_wire_mask(&bad, 3, d).is_err());
        // out-of-range index
        let oob = MaskWire::IndexList {
            d,
            idx: vec![1, 5, 64],
        };
        assert!(check_wire_mask(&oob, 3, d).is_err());
        // bitset with a padding bit set beyond d
        let pad = MaskWire::Bitset {
            d: 10,
            bits: vec![0b0000_0001, 0b1000_0000],
        };
        assert!(check_wire_mask(&pad, 2, 10).is_err());
        // bitset of the wrong length
        let short = MaskWire::Bitset {
            d,
            bits: vec![0xff],
        };
        assert!(check_wire_mask(&short, 8, d).is_err());
    }
}
