"""Tiled Pallas matmul with fused bias + activation epilogue.

TPU adaptation of the model's dense-layer hot path (DESIGN.md
§Hardware-Adaptation): blocks are staged HBM->VMEM via ``BlockSpec``; the
inner ``jnp.dot`` maps onto the MXU with an f32 accumulator carried across
the K grid dimension (the output block's index_map ignores k, so the block
stays resident in VMEM across sequential K steps). The CUDA analogue would
be a threadblock-tiled GEMM with a shared-memory epilogue; here the K-loop
is a grid dimension and the epilogue (bias add + activation) runs on the
final K step only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile. Clamped to divisors of the problem size.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (falls back to dim)."""
    if dim <= pref:
        return dim
    for b in range(pref, 0, -1):
        if dim % b == 0:
            return b
    return dim


def _kernel_nobias(x_ref, w_ref, o_ref, *, nk: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out = o_ref[...]
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        elif act == "tanh":
            out = jnp.tanh(out)
        o_ref[...] = out


def _kernel_bias(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...]
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        elif act == "tanh":
            out = jnp.tanh(out)
        o_ref[...] = out


def _pallas_matmul(x, w, b, *, act: str, bm: int, bn: int, bk: int,
                   interpret: bool):
    """Raw (non-differentiable) tiled Pallas ``act(x @ w [+ b])``."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"inner dims mismatch: {kdim} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(kdim, bk)
    nk = kdim // bk
    grid = (m // bm, n // bn, nk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)

    if b is None:
        return pl.pallas_call(
            functools.partial(_kernel_nobias, nk=nk, act=act),
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(x, w)

    # Bias is broadcast along M: block (1, bn), replicated over i and k.
    b_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))
    return pl.pallas_call(
        functools.partial(_kernel_bias, nk=nk, act=act),
        grid=grid,
        in_specs=[x_spec, w_spec, b_spec],
        out_specs=o_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(x, w, b.reshape(1, n))


@functools.lru_cache(maxsize=None)
def _make_op(act: str, bm: int, bn: int, bk: int, interpret: bool):
    """Build a custom-VJP matmul op for a given (act, tiling) config.

    ``pallas_call`` has no general autodiff rule (the K-grid accumulator +
    ``pl.when`` epilogue defeat the built-in JVP), so we supply the VJP
    ourselves — and route the backward GEMMs through the same Pallas kernel,
    keeping L1 on the hot path of both fwd and bwd:

        dpre = dy * act'(out)
        dx   = dpre @ w.T        (Pallas)
        dw   = x.T  @ dpre       (Pallas)
        db   = sum_M dpre
    """

    def raw(x, w, b, a):
        return _pallas_matmul(x, w, b, act=a, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)

    @jax.custom_vjp
    def op(x, w, b):
        return raw(x, w, b, act)

    def fwd(x, w, b):
        out = raw(x, w, b, act)
        return out, (x, w, out)

    def bwd(res, dy):
        x, w, out = res
        if act == "relu":
            dpre = dy * (out > 0).astype(dy.dtype)
        elif act == "tanh":
            dpre = dy * (1.0 - out * out)
        else:
            dpre = dy
        dx = raw(dpre, w.T, None, "none")
        dw = raw(x.T, dpre, None, "none")
        db = jnp.sum(dpre, axis=0)
        return dx, dw, db

    op.defvjp(fwd, bwd)
    return op


@functools.partial(
    jax.jit, static_argnames=("act", "bm", "bn", "bk", "interpret")
)
def matmul_bias_act(x, w, b=None, *, act: str = "none", bm: int = DEFAULT_BM,
                    bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                    interpret: bool = True):
    """``act(x @ w + b)`` as a tiled, differentiable Pallas kernel.

    Args:
      x: f32[M, K]. w: f32[K, N]. b: optional f32[N].
      act: "none" | "relu" | "tanh" epilogue, fused into the last K step.
      bm/bn/bk: preferred VMEM block sizes (clamped to divisors of M/N/K).
      interpret: must stay True on CPU PJRT (see module docstring).

    Returns:
      f32[M, N].
    """
    op = _make_op(act, bm, bn, bk, interpret)
    if b is None:
        b = jnp.zeros((w.shape[1],), jnp.float32)
    return op(x, w, b)


def matmul(x, w, **kw):
    """Plain ``x @ w`` (no bias, no activation epilogue)."""
    return matmul_bias_act(x, w, None, act="none", **kw)
