//! TOML-subset parser for experiment configs.
//!
//! Supported grammar (everything our configs use — see `configs/*.toml`):
//!
//! ```text
//! # comment
//! [section]
//! key = "string" | 123 | 4.5 | true | false | [v, v, ...]
//! ```
//!
//! Not supported (by design): nested tables, dotted keys, dates,
//! multi-line strings. Unknown syntax is a hard error, not a silent skip.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: `(section, key) -> value`. Top-level keys use
/// section `""`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section"))?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty()
                || !key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(err(lineno, &format!("bad key '{key}'")));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| err(lineno, &e))?;
            doc.entries
                .insert((section.clone(), key.to_string()), value);
        }
        Ok(doc)
    }

    pub fn parse_file(path: &str) -> Result<TomlDoc, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        Self::parse(&src)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// All (section, key) pairs present.
    pub fn keys(&self) -> impl Iterator<Item = &(String, String)> {
        self.entries.keys()
    }
}

fn err(lineno: usize, msg: &str) -> String {
    format!("config line {}: {msg}", lineno + 1)
}

/// Strip a `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                out.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split an array body on commas (no nested arrays in our subset, but keep
/// string-awareness).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Quote a CLI-provided value unless it already parses as a bare TOML value
/// (used by `ExperimentConfig::set`).
pub fn quote_if_needed(v: &str) -> String {
    if parse_value(v).is_ok() {
        v.to_string()
    } else {
        format!("\"{v}\"")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hello" # comment
            i = -42
            f = 0.05
            b = true
            arr = [0.01, 0.05, 0.1]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("a", "s").unwrap().as_str(), Some("hello"));
        assert_eq!(doc.get("a", "i").unwrap().as_f64(), Some(-42.0));
        assert_eq!(doc.get("a", "f").unwrap().as_f64(), Some(0.05));
        assert_eq!(doc.get("a", "b").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("a", "arr").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_are_loud() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("bad key! = 1").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = TomlDoc::parse("a = []").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn quote_if_needed_behaviour() {
        assert_eq!(quote_if_needed("0.5"), "0.5");
        assert_eq!(quote_if_needed("true"), "true");
        assert_eq!(quote_if_needed("alie"), "\"alie\"");
    }
}
