#!/usr/bin/env python3
"""Validate rosdhb telemetry artifacts (stdlib only; CI smoke gate).

Usage:
    python3 scripts/check_trace.py TRACE.jsonl [TRACE.jsonl.w0 ...] \
        [--status status.json] [--report report.json]

Each trace file must be well-formed JSONL: every line a JSON object
naming a known event, carrying that event's required keys, with
monotonically non-decreasing ``ts_us`` and (for ``round_phase``)
non-decreasing round numbers. ``--status`` checks one snapshot from the
live status endpoint; ``--report`` checks the run report printed by a
traced ``rosdhb serve``/``train`` (which must carry the ``telemetry``
section exactly when tracing was on).
"""

import argparse
import json
import sys

# event name -> keys required alongside "event" and "ts_us"
EVENT_KEYS = {
    "round_phase": {"round", "phase", "micros"},
    "worker_evicted": {"round", "worker", "reason"},
    "relay_resync": {"worker"},
    "epoch_transition": {"epoch", "round"},
    "checkpoint_written": {"round", "path"},
    "rendezvous_admit": {"worker", "peer"},
    "rendezvous_leave": {"worker"},
    "rendezvous_reject": {"peer", "reason"},
    "agg_forensics": {
        "round",
        "selected",
        "neighbor_rows",
        "weiszfeld_iters",
        "weiszfeld_residual",
        "trim_cols",
    },
    "suspicion_snapshot": {"round", "suspicion"},
    "worker_round": {"round", "wait_us", "compute_us", "reply_us"},
    "clock_sync": {"offset_us", "rtt_us"},
}

PHASES = ("broadcast", "collect", "aggregate", "apply")

STATUS_KEYS = {
    "algorithm",
    "rounds_total",
    "round",
    "epoch",
    "live_slots",
    "slots",
    "uplink_bytes",
    "coordinator_ingress_bytes",
    "relayed_uplink_bytes",
    "downlink_bytes",
    "coordinator_egress_bytes",
    "relayed_downlink_bytes",
    "relay_resyncs",
    "evictions",
    "net",
    "lyapunov",
    "trace_events",
    "geometry",
    "suspicion",
    "workers",
}


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_trace(path):
    last_ts = -1
    last_round = 0
    counts = {}
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                fail(f"{path}:{lineno}: blank line in JSONL journal")
            try:
                ev = json.loads(line)
            except ValueError as e:
                fail(f"{path}:{lineno}: not JSON: {e}")
            if not isinstance(ev, dict):
                fail(f"{path}:{lineno}: not an object")
            name = ev.get("event")
            if name not in EVENT_KEYS:
                fail(f"{path}:{lineno}: unknown event {name!r}")
            missing = EVENT_KEYS[name] - ev.keys()
            if missing:
                fail(f"{path}:{lineno}: {name} missing {sorted(missing)}")
            ts = ev.get("ts_us")
            if not isinstance(ts, int) or ts < last_ts:
                fail(f"{path}:{lineno}: ts_us {ts!r} not monotone")
            last_ts = ts
            if name == "round_phase":
                if ev["phase"] not in PHASES:
                    fail(f"{path}:{lineno}: unknown phase {ev['phase']!r}")
                if ev["round"] < last_round:
                    fail(
                        f"{path}:{lineno}: round_phase round went backwards "
                        f"({last_round} -> {ev['round']})"
                    )
                last_round = ev["round"]
            counts[name] = counts.get(name, 0) + 1
    if not counts:
        fail(f"{path}: journal is empty")
    summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"check_trace: {path}: OK ({summary})")
    return counts


def check_status(path):
    with open(path, encoding="utf-8") as fh:
        snap = json.load(fh)
    missing = STATUS_KEYS - snap.keys()
    if missing:
        fail(f"{path}: status snapshot missing {sorted(missing)}")
    if not isinstance(snap["slots"], list):
        fail(f"{path}: slots is not an array")
    live = sum(1 for s in snap["slots"] if s.get("active"))
    if snap["live_slots"] != live:
        fail(
            f"{path}: live_slots={snap['live_slots']} but {live} slots "
            "are active"
        )
    if snap["relayed_downlink_bytes"] != (
        snap["downlink_bytes"] - snap["coordinator_egress_bytes"]
    ):
        fail(f"{path}: relayed_downlink_bytes breaks the byte identity")
    if snap["relayed_uplink_bytes"] != (
        snap["uplink_bytes"] - snap["coordinator_ingress_bytes"]
    ):
        fail(f"{path}: relayed_uplink_bytes breaks the byte identity")
    print(
        f"check_trace: {path}: OK (round {snap['round']}/"
        f"{snap['rounds_total']}, {snap['live_slots']} live)"
    )


def check_report(path):
    with open(path, encoding="utf-8") as fh:
        rep = json.load(fh)
    tel = rep.get("telemetry")
    if tel is None:
        fail(f"{path}: traced run report has no telemetry section")
    for key in ("phases", "worker_latency", "relayed_downlink_bytes"):
        if key not in tel:
            fail(f"{path}: telemetry section missing {key!r}")
    for phase in PHASES:
        if phase not in tel["phases"]:
            fail(f"{path}: telemetry.phases missing {phase!r}")
    for key in ("geometry", "suspicion"):
        if key not in rep:
            fail(f"{path}: traced report missing {key!r}")
    if not isinstance(rep["suspicion"], list):
        fail(f"{path}: report suspicion is not an array")
    print(f"check_trace: {path}: OK (telemetry section present)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("traces", nargs="+", help="JSONL trace files")
    ap.add_argument("--status", help="status endpoint snapshot to validate")
    ap.add_argument("--report", help="traced run report JSON to validate")
    args = ap.parse_args()
    for path in args.traces:
        check_trace(path)
    if args.status:
        check_status(args.status)
    if args.report:
        check_report(args.report)


if __name__ == "__main__":
    main()
