//! Per-round metrics, CSV export, and the paper's time/bytes-to-τ readout.

use std::io::Write;

/// One row of the training log.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean honest training loss this round (from worker gradient passes).
    pub train_loss: f64,
    /// ‖aggregate R^t‖ — the applied update direction's norm.
    pub update_norm: f64,
    /// Test accuracy if evaluated this round.
    pub test_acc: Option<f64>,
    /// Cumulative uplink bytes after this round.
    pub uplink_bytes: u64,
    /// Cumulative downlink bytes after this round.
    pub downlink_bytes: u64,
    /// Lyapunov diagnostics if enabled: (‖δᵗ‖², Υᵗ).
    pub lyapunov: Option<(f64, f64)>,
}

/// Whole-run log + summary extraction.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsLog {
    pub rows: Vec<RoundRecord>,
}

impl MetricsLog {
    pub fn push(&mut self, r: RoundRecord) {
        self.rows.push(r);
    }

    /// First round whose evaluated test accuracy ≥ tau.
    pub fn rounds_to_tau(&self, tau: f64) -> Option<usize> {
        self.rows
            .iter()
            .find(|r| r.test_acc.is_some_and(|a| a >= tau))
            .map(|r| r.round)
    }

    /// Cumulative uplink bytes at the τ-crossing round (Fig. 1 y-axis).
    pub fn uplink_bytes_to_tau(&self, tau: f64) -> Option<u64> {
        self.rows
            .iter()
            .find(|r| r.test_acc.is_some_and(|a| a >= tau))
            .map(|r| r.uplink_bytes)
    }

    /// Total (uplink + downlink) bytes at the τ-crossing round.
    pub fn total_bytes_to_tau(&self, tau: f64) -> Option<u64> {
        self.rows
            .iter()
            .find(|r| r.test_acc.is_some_and(|a| a >= tau))
            .map(|r| r.uplink_bytes + r.downlink_bytes)
    }

    /// Best test accuracy seen.
    pub fn best_acc(&self) -> Option<f64> {
        self.rows
            .iter()
            .filter_map(|r| r.test_acc)
            .fold(None, |m, a| Some(m.map_or(a, |m: f64| m.max(a))))
    }

    /// Final train loss.
    pub fn final_loss(&self) -> Option<f64> {
        self.rows.last().map(|r| r.train_loss)
    }

    /// Write the log as CSV.
    pub fn write_csv<W: Write>(&self, mut w: W) -> std::io::Result<()> {
        writeln!(
            w,
            "round,train_loss,update_norm,test_acc,uplink_bytes,downlink_bytes,delta_sq,upsilon"
        )?;
        for r in &self.rows {
            writeln!(
                w,
                "{},{},{},{},{},{},{},{}",
                r.round,
                r.train_loss,
                r.update_norm,
                r.test_acc.map_or(String::new(), |a| a.to_string()),
                r.uplink_bytes,
                r.downlink_bytes,
                r.lyapunov
                    .map_or(String::new(), |(d, _)| d.to_string()),
                r.lyapunov
                    .map_or(String::new(), |(_, u)| u.to_string()),
            )?;
        }
        Ok(())
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        let f = std::fs::File::create(path)?;
        self.write_csv(std::io::BufWriter::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: usize, acc: Option<f64>, up: u64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            update_norm: 0.5,
            test_acc: acc,
            uplink_bytes: up,
            downlink_bytes: 2 * up,
            lyapunov: None,
        }
    }

    #[test]
    fn tau_crossing() {
        let mut log = MetricsLog::default();
        log.push(row(0, Some(0.3), 100));
        log.push(row(10, None, 200));
        log.push(row(20, Some(0.9), 300));
        log.push(row(30, Some(0.95), 400));
        assert_eq!(log.rounds_to_tau(0.85), Some(20));
        assert_eq!(log.uplink_bytes_to_tau(0.85), Some(300));
        assert_eq!(log.total_bytes_to_tau(0.85), Some(900));
        assert_eq!(log.rounds_to_tau(0.99), None);
        assert_eq!(log.best_acc(), Some(0.95));
    }

    #[test]
    fn csv_shape() {
        let mut log = MetricsLog::default();
        log.push(row(0, Some(0.5), 10));
        log.push(RoundRecord {
            lyapunov: Some((0.25, 1.5)),
            ..row(1, None, 20)
        });
        let mut buf = Vec::new();
        log.write_csv(&mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("round,train_loss"));
        assert!(lines[1].contains("0.5"));
        assert!(lines[2].ends_with("0.25,1.5"));
    }
}
