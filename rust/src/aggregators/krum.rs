//! Krum and Multi-Krum (Blanchard et al. [7]).
//!
//! Krum scores each input by the sum of squared distances to its
//! n−f−2 nearest other inputs and returns the argmin; Multi-Krum averages
//! the m = n−f best-scored inputs. Both are pure **selection** rules:
//! their only use of the inputs' vector structure is through pairwise
//! distances, so they consume a prepared [`Geometry`] view
//! ([`Aggregator::geometry_backed`]) — the dense entry point builds a
//! one-shot matrix ([`geometry::pairwise_dist_sq`], O(n²d)) while the
//! sparse round engine hands them the incrementally maintained one
//! (O(n²k) per round). Either way the output is copied/averaged straight
//! from the input rows, so results are bit-identical whenever the
//! selected set agrees.

use super::geometry::{self, GeoCtx, Geometry};
use super::{delta_ratio, Aggregator};
use crate::telemetry::forensics;

/// Krum scores: per input, the sum of its n−f−2 smallest distances to
/// the other inputs. One scratch buffer is reused across rows and the
/// partial selection (`select_nth_unstable_by`) replaces the former
/// per-row allocate-and-full-sort.
pub(crate) fn scores(geo: &Geometry<'_>, f: usize) -> Vec<f64> {
    let n = geo.n();
    let closest = n.saturating_sub(f + 2).max(1).min(n - 1);
    let mut scratch: Vec<f64> = Vec::with_capacity(n - 1);
    (0..n)
        .map(|i| {
            scratch.clear();
            let row = geo.row(i);
            scratch.extend(
                row.iter()
                    .enumerate()
                    .filter(|&(j, _)| j != i)
                    .map(|(_, &d)| d),
            );
            if closest < scratch.len() {
                scratch
                    .select_nth_unstable_by(closest - 1, |a, b| a.total_cmp(b));
            }
            scratch[..closest].iter().sum()
        })
        .collect()
}

/// Krum's argmin over [`scores`]. Ties resolve identically on every call
/// path (same comparator, same iteration order), so the selection — and
/// therefore the copied output — agrees between the dense and geometry
/// entry points whenever the distances do.
pub(crate) fn krum_select(geo: &Geometry<'_>, f: usize) -> usize {
    let sc = scores(geo, f);
    let best = (0..geo.n())
        .min_by(|&a, &b| sc[a].total_cmp(&sc[b]))
        .expect("krum needs at least one input");
    // observation only (no-ops unless the trainer armed forensics):
    // both the dense and geometry entry paths route through here, so
    // every Krum round reports its scores and pick
    forensics::note_scores(&sc);
    forensics::note_selected(&[best]);
    forensics::note_pairwise(geo);
    best
}

/// Multi-Krum's m = n−f best-scored inputs, returned **ascending by
/// index** so the averaging order is pinned by the selected *set* alone
/// (score order may drift between refreshes without changing the sum).
///
/// Partial selection (`select_nth_unstable_by`) on the total order
/// (score, index) replaces the former full `O(n log n)` stable sort:
/// ties at the m-th score resolve by index exactly as the stable sort
/// did, so the selected set — and the averaged output — is bit-identical.
pub(crate) fn multikrum_select(geo: &Geometry<'_>, f: usize) -> Vec<usize> {
    let n = geo.n();
    let m = n - f;
    let sc = scores(geo, f);
    let mut order: Vec<usize> = (0..n).collect();
    let cmp =
        |a: &usize, b: &usize| sc[*a].total_cmp(&sc[*b]).then(a.cmp(b));
    if m < n {
        order.select_nth_unstable_by(m - 1, cmp);
        order.truncate(m);
    }
    order.sort_unstable();
    forensics::note_scores(&sc);
    forensics::note_selected(&order);
    forensics::note_pairwise(geo);
    order
}

#[derive(Clone, Debug)]
pub struct Krum {
    pub f: usize,
}

impl Krum {
    pub fn new(f: usize) -> Self {
        Krum { f }
    }
}

impl Aggregator for Krum {
    fn name(&self) -> String {
        format!("krum(f={})", self.f)
    }

    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        let n = inputs.len();
        assert!(n > 2, "krum needs n > 2");
        let dist = geometry::pairwise_dist_sq(inputs);
        let best = krum_select(&Geometry::new(n, &dist), self.f);
        out.copy_from_slice(inputs[best]);
    }

    /// Selection uses full-space distances, so Krum is not
    /// coordinate-separable: `aggregate_block` (trait default) is
    /// block-local. The sparse round engine instead reaches it through
    /// the geometry path.
    fn coordinate_separable(&self) -> bool {
        false
    }

    fn geometry_backed(&self) -> bool {
        true
    }

    /// Geometry → argmin → O(d) row copy: bit-identical to the dense
    /// oracle whenever the selection agrees.
    fn aggregate_geo(
        &self,
        inputs: &[&[f32]],
        ctx: &mut GeoCtx<'_>,
        out: &mut [f32],
    ) {
        assert!(inputs.len() > 2, "krum needs n > 2");
        let best = krum_select(&ctx.geo, self.f);
        out.copy_from_slice(inputs[best]);
    }

    /// Krum's κ does not vanish with n (stays Θ(1)); bound from [2]:
    /// κ ≤ 6(1 + δ/(1−2δ))² — constants conservative.
    fn kappa(&self, n: usize, f: usize) -> f64 {
        if f == 0 {
            // still selects a single vector != mean: κ is O(1), not 0.
            return 1.0;
        }
        if n <= 2 * f {
            return f64::INFINITY;
        }
        let r = delta_ratio(n, f);
        6.0 * (1.0 + r) * (1.0 + r)
    }
}

/// Multi-Krum: average of the n−f best-scored inputs (summed in
/// ascending-index order — see [`multikrum_select`]).
#[derive(Clone, Debug)]
pub struct MultiKrum {
    pub f: usize,
}

impl MultiKrum {
    pub fn new(f: usize) -> Self {
        MultiKrum { f }
    }

    /// The averaging stage is a plain ordered row mean — it goes through
    /// the uplink module's one pinned summation-order authority
    /// ([`crate::transport::uplink::ordered_mean_into`], bit-identical
    /// to [`crate::tensor::mean_into`] by test), the same order every
    /// aggregated-uplink fold reproduces.
    fn average_selected(
        &self,
        inputs: &[&[f32]],
        selected: &[usize],
        out: &mut [f32],
    ) {
        let rows: Vec<&[f32]> = selected.iter().map(|&i| inputs[i]).collect();
        crate::transport::uplink::ordered_mean_into(out, &rows);
    }
}

impl Aggregator for MultiKrum {
    fn name(&self) -> String {
        format!("multikrum(f={})", self.f)
    }

    fn aggregate(&self, inputs: &[&[f32]], out: &mut [f32]) {
        let n = inputs.len();
        assert!(n > self.f, "multikrum needs n > f");
        let dist = geometry::pairwise_dist_sq(inputs);
        let selected = multikrum_select(&Geometry::new(n, &dist), self.f);
        self.average_selected(inputs, &selected, out);
    }

    fn geometry_backed(&self) -> bool {
        true
    }

    /// Geometry → selected set → O((n−f)·d) mean of input rows:
    /// bit-identical to the dense oracle whenever the set agrees.
    fn aggregate_geo(
        &self,
        inputs: &[&[f32]],
        ctx: &mut GeoCtx<'_>,
        out: &mut [f32],
    ) {
        assert!(inputs.len() > self.f, "multikrum needs n > f");
        let selected = multikrum_select(&ctx.geo, self.f);
        self.average_selected(inputs, &selected, out);
    }

    fn kappa(&self, n: usize, f: usize) -> f64 {
        if f == 0 {
            return 0.0; // selects everyone -> exact mean
        }
        if n <= 2 * f {
            return f64::INFINITY;
        }
        let r = delta_ratio(n, f);
        6.0 * r * (1.0 + r)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::*;
    use super::super::Aggregator;
    use super::*;
    use crate::tensor;

    #[test]
    fn krum_picks_a_cluster_member() {
        let rows = corrupted_inputs(9, 2, 6, 1e5, 2);
        let refs = as_refs(&rows);
        let out = Krum::new(2).aggregate_vec(&refs);
        // output must be one of the honest inputs (3..9)
        let is_honest = rows[2..].iter().any(|r| r.as_slice() == &out[..]);
        assert!(is_honest);
    }

    #[test]
    fn multikrum_excludes_outliers() {
        let rows = corrupted_inputs(10, 3, 6, 1e5, 4);
        let refs = as_refs(&rows);
        let out = MultiKrum::new(3).aggregate_vec(&refs);
        assert!(tensor::norm(&out) < 5.0, "‖out‖ = {}", tensor::norm(&out));
    }

    #[test]
    fn multikrum_f0_is_mean() {
        let rows = corrupted_inputs(6, 0, 4, 0.0, 6);
        let refs = as_refs(&rows);
        let got = MultiKrum::new(0).aggregate_vec(&refs);
        let want = crate::aggregators::Mean.aggregate_vec(&refs);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6);
        }
    }

    #[test]
    fn pairwise_matrix_symmetric_zero_diag() {
        let rows = corrupted_inputs(5, 0, 3, 0.0, 7);
        let refs = as_refs(&rows);
        let m = geometry::pairwise_dist_sq(&refs);
        for i in 0..5 {
            assert_eq!(m[i * 5 + i], 0.0);
            for j in 0..5 {
                assert_eq!(m[i * 5 + j], m[j * 5 + i]);
            }
        }
    }

    #[test]
    fn scores_select_nth_matches_full_sort_reference() {
        // the partial-selection scores must sum the same multiset of
        // distances the old full sort did
        let rows = corrupted_inputs(9, 2, 5, 1e3, 8);
        let refs = as_refs(&rows);
        let dist = geometry::pairwise_dist_sq(&refs);
        let n = refs.len();
        let f = 2;
        let geo = Geometry::new(n, &dist);
        let got = scores(&geo, f);
        let closest = n - f - 2;
        for (i, g) in got.iter().enumerate() {
            let mut row: Vec<f64> = (0..n)
                .filter(|&j| j != i)
                .map(|j| dist[i * n + j])
                .collect();
            row.sort_by(|a, b| a.total_cmp(b));
            let want: f64 = row[..closest].iter().sum();
            assert!(
                (g - want).abs() <= 1e-12 * want.abs().max(1.0),
                "row {i}: {g} vs {want}"
            );
        }
    }

    #[test]
    fn multikrum_partial_selection_matches_stable_sort_reference() {
        // the select_nth path must pick the same set as the former full
        // stable sort, including through score ties (duplicated rows)
        let mut rows = corrupted_inputs(10, 2, 6, 1e4, 21);
        rows[5] = rows[4].clone(); // exact tie
        rows[7] = rows[6].clone();
        let refs = as_refs(&rows);
        let n = refs.len();
        let dist = geometry::pairwise_dist_sq(&refs);
        let geo = Geometry::new(n, &dist);
        for f in [0usize, 1, 2, 4] {
            let got = multikrum_select(&geo, f);
            // reference: the old implementation (stable sort by score,
            // truncate, sort by index)
            let sc = scores(&geo, f);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| sc[a].total_cmp(&sc[b]));
            order.truncate(n - f);
            order.sort_unstable();
            assert_eq!(got, order, "f={f}");
        }
    }

    #[test]
    fn geometry_entry_points_match_dense_on_exact_distances() {
        let rows = corrupted_inputs(10, 3, 12, 1e4, 9);
        let refs = as_refs(&rows);
        let n = refs.len();
        for f in [0usize, 3] {
            let dist = geometry::pairwise_dist_sq(&refs);
            let mut geo = geometry::PairwiseGeometry::new(
                n,
                geometry::RefreshPeriod::Never,
            );
            geo.rebuild(&refs);
            let krum = Krum::new(f.max(1));
            let dense = krum.aggregate_vec(&refs);
            let mut got = vec![0f32; 12];
            krum.aggregate_geo(&refs, &mut geo.ctx(None, false), &mut got);
            assert_eq!(dense, got, "krum f={f}");

            let mk = MultiKrum::new(f);
            let dense = mk.aggregate_vec(&refs);
            let mut got = vec![0f32; 12];
            mk.aggregate_geo(&refs, &mut geo.ctx(None, false), &mut got);
            assert_eq!(dense, got, "multikrum f={f}");
            // selection itself is consistent between one-shot and
            // maintained matrices built from the same inputs
            let view = Geometry::new(n, &dist);
            assert_eq!(
                multikrum_select(&view, f),
                multikrum_select(&geo.ctx(None, false).geo, f)
            );
        }
    }
}
