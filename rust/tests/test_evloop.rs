//! Loopback integration tests for the event-loop socket runtime
//! (`io = "evloop"`): the readiness-based transport must reproduce the
//! threaded transport — and the in-process oracle — bit for bit, on the
//! per-round log and on the cumulative wire-byte counters, across flat
//! and relay-tree fan-out. The stalled-relay regression pins the PR 5
//! gap: a relay that *delays* (without dying) past the gap monitor's
//! threshold costs nobody the round — its children RESYNC to direct
//! delivery before the deadline and no subtree is evicted.

use rosdhb::config::ExperimentConfig;
use rosdhb::coordinator::round_transport::TcpTransport;
use rosdhb::coordinator::{RunReport, Trainer};
use rosdhb::model::MlpSpec;
use rosdhb::transport::evloop::ServerIo;
use rosdhb::transport::net::NetStats;
use rosdhb::worker::remote::{join_run, JoinOpts, JoinSummary};
use std::thread;
use std::time::Duration;

fn base_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default_mnist_like();
    c.n_honest = 4;
    c.n_byz = 0;
    c.attack = "none".into();
    c.aggregator = "cwtm".into();
    c.k_frac = 0.1;
    c.rounds = 5;
    c.eval_every = 2;
    c.batch = 30;
    c.train_size = 600;
    c.test_size = 200;
    c.stop_at_tau = false;
    c.seed = 7;
    c.transport = "tcp".into();
    c.round_timeout_ms = 20_000;
    c
}

/// Run `cfg` over loopback TCP with the socket runtime `cfg.io` names:
/// coordinator on this thread, one worker thread per slot (every worker
/// gets the same `opts`). Returns the report, the measured socket
/// traffic, and each worker's outcome.
fn run_io(
    cfg: &ExperimentConfig,
    opts: JoinOpts,
) -> (RunReport, NetStats, Vec<anyhow::Result<JoinSummary>>) {
    let server = ServerIo::bind("127.0.0.1:0", &cfg.io).unwrap();
    let addr = server.local_addr().to_string();
    let handles: Vec<_> = (0..cfg.n_total())
        .map(|_| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            let opts = opts.clone();
            thread::spawn(move || {
                join_run(&cfg, &addr, Duration::from_secs(20), opts)
            })
        })
        .collect();
    let d = MlpSpec::default().p();
    let transport = TcpTransport::rendezvous_io(server, cfg, d).unwrap();
    let mut trainer = Trainer::with_transport(cfg, Box::new(transport)).unwrap();
    let report = trainer.run().unwrap();
    let stats = trainer.net_stats().unwrap();
    trainer.shutdown_transport(); // BYE — releases the worker threads
    let outcomes = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (report, stats, outcomes)
}

fn run_local(cfg: &ExperimentConfig) -> RunReport {
    let mut local = cfg.clone();
    local.transport = "local".into();
    Trainer::from_config(&local).unwrap().run().unwrap()
}

/// Every field that must match for "bit-identical RunReport".
fn assert_reports_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.algorithm, b.algorithm);
    assert_eq!(a.rounds_run, b.rounds_run);
    assert_eq!(a.rounds_to_tau, b.rounds_to_tau);
    assert_eq!(a.uplink_bytes_to_tau, b.uplink_bytes_to_tau);
    assert_eq!(a.uplink_bytes, b.uplink_bytes);
    assert_eq!(a.downlink_bytes, b.downlink_bytes);
    assert_eq!(a.coordinator_egress_bytes, b.coordinator_egress_bytes);
    assert_eq!(a.best_acc, b.best_acc);
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(a.log.rows.len(), b.log.rows.len());
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.update_norm, rb.update_norm, "round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "round {}", ra.round);
        assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "round {}", ra.round);
        assert_eq!(ra.downlink_bytes, rb.downlink_bytes, "round {}", ra.round);
    }
}

/// Shared body of the io-mode parity matrix: run `cfg` under both socket
/// runtimes and locally; all three reports must be bit-identical, both
/// measured byte counters must match the `ByteMeter` model — and the two
/// runtimes must agree down to *raw* socket bytes (frame envelopes,
/// handshakes, PLAN frames), the strictest cross-runtime invariant.
fn assert_io_parity(cfg: &ExperimentConfig) {
    let mut threads_cfg = cfg.clone();
    threads_cfg.io = "threads".into();
    let mut evloop_cfg = cfg.clone();
    evloop_cfg.io = "evloop".into();

    let (rep_t, st_t, out_t) = run_io(&threads_cfg, JoinOpts::default());
    let (rep_e, st_e, out_e) = run_io(&evloop_cfg, JoinOpts::default());
    for o in out_t.iter().chain(&out_e) {
        let s = o.as_ref().expect("worker must finish cleanly");
        assert_eq!(s.rounds, cfg.rounds as u64);
        assert_eq!(s.resyncs, 0, "no-fault run must never resync");
    }

    let local = run_local(cfg);
    assert_reports_identical(&rep_e, &local);
    assert_reports_identical(&rep_e, &rep_t);

    for (stats, tag) in [(&st_t, "threads"), (&st_e, "evloop")] {
        assert_eq!(stats.wire_uplink, rep_e.uplink_bytes, "{tag} uplink");
        assert_eq!(
            stats.wire_downlink, rep_e.downlink_bytes,
            "{tag} downlink"
        );
        assert!(stats.raw_uplink > stats.wire_uplink, "{tag}");
        assert!(stats.raw_downlink > stats.wire_downlink, "{tag}");
    }
    assert_eq!(st_t.raw_uplink, st_e.raw_uplink, "raw uplink");
    assert_eq!(st_t.raw_downlink, st_e.raw_downlink, "raw downlink");
}

#[test]
fn evloop_flat_rosdhb_is_bit_identical_to_threads_and_local() {
    assert_io_parity(&base_cfg());
}

#[test]
fn evloop_flat_qsgd_quantized_payloads_keep_parity() {
    // a second wire plan through the nonblocking frame reader: bit-packed
    // QuantBlock uplinks exercise the split GRAD decode path with bodies
    // whose size is not a multiple of anything convenient
    let mut cfg = base_cfg();
    cfg.set("algorithm", "rosdhb-u").unwrap();
    cfg.set("compressor", "qsgd:4").unwrap();
    cfg.rounds = 3;
    assert_io_parity(&cfg);
}

#[test]
fn evloop_relay_tree_keeps_parity_across_runtimes() {
    // the relay tree under the event loop: PLAN delivery, single-thread
    // child accept/forward, and the per-worker EvFeed must leave the
    // report and every byte counter exactly where the threaded TreeFeed
    // puts them
    let mut cfg = base_cfg();
    cfg.set("fanout", "tree").unwrap();
    cfg.set("branching", "2").unwrap();
    assert_io_parity(&cfg);
}

#[test]
fn evloop_flat_interops_with_threads_coordinator() {
    // `io` is deliberately absent from the wire fingerprint: under flat
    // fan-out an evloop *worker config* joins a threads coordinator (and
    // vice versa) because both speak the identical wire format. Run
    // workers configured io=evloop against a threads server.
    let mut server_cfg = base_cfg();
    server_cfg.io = "threads".into();
    server_cfg.rounds = 3;
    let mut worker_cfg = server_cfg.clone();
    worker_cfg.io = "evloop".into();
    assert_eq!(
        server_cfg.wire_fingerprint(),
        worker_cfg.wire_fingerprint()
    );

    let server = ServerIo::bind("127.0.0.1:0", &server_cfg.io).unwrap();
    let addr = server.local_addr().to_string();
    let handles: Vec<_> = (0..worker_cfg.n_total())
        .map(|_| {
            let cfg = worker_cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || {
                join_run(
                    &cfg,
                    &addr,
                    Duration::from_secs(20),
                    JoinOpts::default(),
                )
            })
        })
        .collect();
    let d = MlpSpec::default().p();
    let transport =
        TcpTransport::rendezvous_io(server, &server_cfg, d).unwrap();
    let mut trainer =
        Trainer::with_transport(&server_cfg, Box::new(transport)).unwrap();
    let report = trainer.run().unwrap();
    trainer.shutdown_transport();
    for h in handles {
        let s = h.join().unwrap().expect("worker must finish cleanly");
        assert_eq!(s.rounds, server_cfg.rounds as u64);
    }
    assert_reports_identical(&report, &run_local(&server_cfg));
}

#[test]
fn stalled_relay_children_resync_before_deadline_no_eviction() {
    // PR 5 left this gap: a relay that stalls *without dying* was
    // indistinguishable from its whole subtree stalling, and the subtree
    // was suspended with it. Under the event loop the children's gap
    // monitor calls the stall and RESYNCs to direct delivery before the
    // round deadline.
    //
    // Every worker gets the same injected fault — sleep 6 s before
    // handling round 6 — so whichever joiner landed in the interior
    // relay slot stalls its subtree; leaf workers merely delay their own
    // reply (well inside the 30 s deadline). By round 6 each child has
    // observed 4 inter-frame gaps, so its monitor is armed; 6 s dwarfs
    // any plausible learned threshold on a loaded CI runner
    // (300 ms floor + 6x the EWMA of loopback round gaps).
    let mut cfg = base_cfg();
    cfg.set("fanout", "tree").unwrap();
    cfg.set("branching", "2").unwrap();
    cfg.io = "evloop".into();
    cfg.rounds = 8;
    cfg.round_timeout_ms = 30_000;
    let stall = JoinOpts {
        stall_relay: Some((6, 6_000)),
        ..Default::default()
    };

    let (report, _stats, outcomes) = run_io(&cfg, stall);

    let summaries: Vec<&JoinSummary> =
        outcomes.iter().map(|o| o.as_ref().unwrap()).collect();
    // no eviction, no suspension: every worker — the stalled relay
    // included — served every round
    for s in &summaries {
        assert_eq!(
            s.rounds, cfg.rounds as u64,
            "worker {} lost rounds to the stalled relay",
            s.worker_id
        );
    }
    // the children actually took the monitor-driven escape hatch
    let resyncs: u32 = summaries.iter().map(|s| s.resyncs).sum();
    assert!(
        resyncs >= 1,
        "no child resynced — the stall was never detected"
    );
    // a relay that stalls forwards every byte eventually; only the
    // delivery *path* changed, so the run is bit-identical to the local
    // oracle on the same config...
    assert_reports_identical(&report, &run_local(&cfg));
    // ...and its numerics are bit-identical to plain flat delivery (the
    // byte columns differ by the fan-out model, the training trajectory
    // must not)
    let mut flat = cfg.clone();
    flat.fanout = "flat".into();
    let flat_local = run_local(&flat);
    assert_eq!(report.rounds_run, flat_local.rounds_run);
    assert_eq!(report.best_acc, flat_local.best_acc);
    assert_eq!(report.final_loss, flat_local.final_loss);
    for (ra, rb) in report.log.rows.iter().zip(&flat_local.log.rows) {
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.update_norm, rb.update_norm, "round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "round {}", ra.round);
    }
}
