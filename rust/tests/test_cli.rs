//! End-to-end CLI tests: drive the compiled `rosdhb` binary the way a
//! user would (cargo exposes the path via `CARGO_BIN_EXE_rosdhb`).

use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_rosdhb"))
}

#[test]
fn info_runs() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("rosdhb"), "{text}");
}

#[test]
fn train_small_run_emits_json_report() {
    let out = bin()
        .args([
            "train",
            "--rounds", "5",
            "--train_size", "500",
            "--test_size", "100",
            "--n_honest", "4",
            "--n_byz", "1",
            "--batch", "20",
            "--stop_at_tau", "false",
            "--eval_every", "5",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8(out.stdout).unwrap();
    let j = rosdhb::util::json::Json::parse(text.trim()).unwrap();
    assert_eq!(j.get("rounds_run").unwrap().as_usize(), Some(5));
    assert!(j.get("uplink_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("config").unwrap().get("algorithm").is_some());
}

#[test]
fn train_rejects_bad_flags() {
    let out = bin()
        .args(["train", "--bogus_key", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bogus_key"), "{err}");

    let out = bin()
        .args(["train", "--n_byz", "10", "--n_honest", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "f >= n/2 must be rejected");
}

#[test]
fn train_with_config_file_and_override() {
    let dir = std::env::temp_dir();
    let cfg = dir.join("rosdhb_cli_test.toml");
    std::fs::write(
        &cfg,
        "[experiment]\nrounds = 4\ntrain_size = 400\ntest_size = 100\n\
         n_honest = 3\nn_byz = 1\nbatch = 20\nstop_at_tau = false\n",
    )
    .unwrap();
    let out = bin()
        .args([
            "train",
            "--config",
            cfg.to_str().unwrap(),
            "--rounds",
            "6", // override wins
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let j = rosdhb::util::json::Json::parse(
        String::from_utf8(out.stdout).unwrap().trim(),
    )
    .unwrap();
    assert_eq!(j.get("rounds_run").unwrap().as_usize(), Some(6));
}

#[test]
fn gb_command_reports_estimates() {
    let out = bin()
        .args([
            "gb",
            "--samples", "4",
            "--train_size", "500",
            "--test_size", "100",
            "--n_honest", "4",
            "--n_byz", "1",
            "--batch", "20",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("G^2=") && text.contains("kappa"), "{text}");
}

#[test]
fn serve_and_join_run_as_separate_os_processes() {
    // n+1 real processes: 1 coordinator + 2 workers over loopback.
    // `serve` binds port 0; its stderr announces the actual address.
    let shared = [
        "--n_honest", "2",
        "--n_byz", "0",
        "--attack", "none",
        "--rounds", "2",
        "--train_size", "400",
        "--test_size", "100",
        "--batch", "20",
        "--eval_every", "2",
        "--stop_at_tau", "false",
        "--k_frac", "0.1",
        "--seed", "5",
    ];
    let mut serve = bin()
        .args(["serve", "--listen_addr", "127.0.0.1:0"])
        .args(shared)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    // scrape "listening on <addr>," off serve's stderr (and keep draining
    // the pipe so the child never blocks on it)
    let stderr = serve.stderr.take().unwrap();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel::<String>();
    let drain = std::thread::spawn(move || {
        let mut all = String::new();
        for line in BufReader::new(stderr).lines() {
            let line = line.unwrap_or_default();
            if let Some(rest) = line.split("listening on ").nth(1) {
                let addr = rest.split(',').next().unwrap_or("").trim();
                let _ = addr_tx.send(addr.to_string());
            }
            all.push_str(&line);
            all.push('\n');
        }
        all
    });
    let addr = addr_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("serve must announce its address");

    let joins: Vec<_> = (0..2)
        .map(|_| {
            bin()
                .args(["join", "--coordinator_addr", &addr])
                .args(shared)
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .unwrap()
        })
        .collect();
    for j in joins {
        let out = j.wait_with_output().unwrap();
        assert!(
            out.status.success(),
            "join failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let status = serve.wait().unwrap();
    let serve_err = drain.join().unwrap();
    assert!(status.success(), "serve failed: {serve_err}");
    assert!(
        serve_err.contains("measured wire bytes"),
        "missing byte report: {serve_err}"
    );
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}
