//! End-to-end three-layer driver (the DESIGN.md validation run):
//! **PJRT engine** — the Rust coordinator executes the AOT-compiled JAX
//! model (with its Pallas kernels) for every honest gradient and every
//! evaluation, trains under ALIE attack with RandK global sparsification
//! and robust aggregation, and logs the loss curve.
//!
//! Requires `make artifacts` first.
//!
//! ```text
//! cargo run --release --example train_e2e [rounds]
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use rosdhb::config::{Engine, ExperimentConfig};
use rosdhb::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("rounds must be an integer"))
        .unwrap_or(300);

    let mut cfg = ExperimentConfig::default_mnist_like();
    cfg.engine = Engine::Pjrt;
    cfg.artifacts_dir = "artifacts".into();
    cfg.n_honest = 10;
    cfg.n_byz = 3;
    cfg.attack = "alie".into();
    cfg.aggregator = "nnm+cwtm".into();
    cfg.k_frac = 0.1;
    cfg.beta = 0.9;
    cfg.gamma = 0.5;
    cfg.gamma_decay = 0.995; // anneal: keeps the late phase stable
    cfg.rounds = rounds;
    cfg.eval_every = 20;
    cfg.train_size = 12_000;
    cfg.test_size = 2_000;
    cfg.stop_at_tau = false;
    cfg.csv_out = Some("train_e2e.csv".into());

    println!("=== three-layer end-to-end run (engine = PJRT) ===");
    println!(
        "model: P=11809 (artifacts), task: synthetic MNIST-like, n={} f={}",
        cfg.n_total(),
        cfg.n_byz
    );
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::from_config(&cfg)?;
    println!("artifact load+compile: {:.2?}", t0.elapsed());

    let t1 = std::time::Instant::now();
    let report = trainer.run()?;
    let dt = t1.elapsed();

    println!("--- loss curve (every eval) ---");
    for row in report.log.rows.iter() {
        if let Some(acc) = row.test_acc {
            println!(
                "round {:5}  loss {:.4}  acc {:.4}  uplink {:>10} B",
                row.round, row.train_loss, acc, row.uplink_bytes
            );
        }
    }
    println!("--- summary ---");
    println!(
        "rounds: {}  wall: {:.2?}  ({:.1} rounds/s)",
        report.rounds_run,
        dt,
        report.rounds_run as f64 / dt.as_secs_f64()
    );
    println!(
        "best acc {:.4} | τ={} reached at {:?} (uplink-to-τ {:?} bytes)",
        report.best_acc.unwrap_or(0.0),
        cfg.tau,
        report.rounds_to_tau,
        report.uplink_bytes_to_tau
    );
    println!("per-round CSV written to train_e2e.csv");
    Ok(())
}
