//! QSGD stochastic quantization (Alistarh et al. [1]) — the second
//! *unbiased* compressor family, used by the Appendix-C generalization of
//! RoSDHB-Local ("RoSDHB-U": any unbiased compressor C with
//! `E[C(x)] = x`, `E‖C(x)‖² ≤ α‖x‖²`).
//!
//! Q_s(x)_i = ‖x‖ · sign(x_i) · ξ_i(x, s), where ξ_i rounds |x_i|/‖x‖·s
//! stochastically to one of the s+1 levels {0, 1/s, …, 1}. Unbiased by
//! construction; ω = E‖Q(x)−x‖²/‖x‖² ≤ min(d/s², √d/s).
//!
//! Wire format: the exact packed layout lives in
//! [`super::payload::QuantBlock`] (2-byte s + 4-byte ‖x‖ + d sign bits +
//! d level fields of ⌈log₂(s+1)⌉ bits); [`Qsgd::wire_bytes`] and the
//! `ByteMeter` model both read that one formula.

use super::payload::QuantBlock;
use crate::prng::Pcg64;

#[derive(Clone, Debug)]
pub struct Qsgd {
    pub d: usize,
    /// Quantization levels s ≥ 1 (s = 1 ⇒ ternary QSGD).
    pub s: u32,
}

impl Qsgd {
    pub fn new(d: usize, s: u32) -> Self {
        assert!(s >= 1);
        Qsgd { d, s }
    }

    /// Variance parameter ω (so α = 1 + ω in the paper's notation).
    pub fn omega(&self) -> f64 {
        let d = self.d as f64;
        let s = self.s as f64;
        (d / (s * s)).min(d.sqrt() / s)
    }

    /// Bits per level index — delegates to the wire-layout authority.
    pub fn level_bits(&self) -> u32 {
        QuantBlock::level_bits(self.s)
    }

    /// Exact uplink wire size of one quantized gradient message (header +
    /// packed [`QuantBlock`] body) — the quantized-payload byte model.
    pub fn wire_bytes(&self) -> usize {
        crate::transport::quant_grad_len(self.d, self.s)
    }

    /// Quantize: returns (norm, levels with sign as i32 in [-s, s]).
    pub fn quantize(&self, x: &[f32], rng: &mut Pcg64) -> (f32, Vec<i32>) {
        let mut levels = Vec::with_capacity(self.d);
        let norm = self.quantize_into(x, rng, &mut levels);
        (norm, levels)
    }

    /// Allocation-free variant of [`Self::quantize`]: levels land in a
    /// caller-owned buffer (cleared, then filled to length d) — the
    /// rosdhb-u hot path reuses one buffer across workers and rounds.
    pub fn quantize_into(
        &self,
        x: &[f32],
        rng: &mut Pcg64,
        levels: &mut Vec<i32>,
    ) -> f32 {
        assert_eq!(x.len(), self.d);
        levels.clear();
        let norm = crate::tensor::norm(x) as f32;
        if norm == 0.0 {
            levels.resize(self.d, 0);
            return 0.0;
        }
        let s = self.s as f32;
        levels.extend(x.iter().map(|&v| {
            let r = v.abs() / norm * s; // in [0, s]
            let lo = r.floor();
            let p = r - lo; // P(round up)
            let l = lo as i32 + if rng.next_f32() < p { 1 } else { 0 };
            if v < 0.0 {
                -l
            } else {
                l
            }
        }));
        norm
    }

    /// Quantize into the typed wire shape ([`QuantBlock`]) — what a
    /// worker-side [`super::CompressorState`] puts on the uplink.
    pub fn quantize_block(&self, x: &[f32], rng: &mut Pcg64) -> QuantBlock {
        let (norm, levels) = self.quantize(x, rng);
        QuantBlock {
            s: self.s,
            norm,
            levels,
        }
    }

    /// Dequantize to the unbiased estimate.
    pub fn reconstruct(&self, norm: f32, levels: &[i32]) -> Vec<f32> {
        assert_eq!(levels.len(), self.d);
        let s = self.s as f32;
        levels
            .iter()
            .map(|&l| norm * l as f32 / s)
            .collect()
    }
}

/// Appendix-C compressor abstraction: any unbiased compressor usable by
/// RoSDHB-Local / the DGD baseline in place of RandK.
pub trait UnbiasedCompressor: Send + Sync {
    fn name(&self) -> String;
    /// Compress-then-reconstruct `g` into `out` (the estimate the server
    /// forms), returning the uplink wire size in bytes.
    fn roundtrip(&self, g: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> usize;
    /// The variance parameter α ≥ 1 of Definition C.1.
    fn alpha(&self) -> f64;
}

impl UnbiasedCompressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd(s={})", self.s)
    }

    fn roundtrip(&self, g: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> usize {
        let (norm, levels) = self.quantize(g, rng);
        let s = self.s as f32;
        for (o, &l) in out.iter_mut().zip(&levels) {
            *o = norm * l as f32 / s;
        }
        self.wire_bytes()
    }

    fn alpha(&self) -> f64 {
        1.0 + self.omega()
    }
}

/// RandK as an [`UnbiasedCompressor`] (local-mask semantics: mask ships
/// with the payload).
#[derive(Clone, Debug)]
pub struct RandKLocal {
    pub inner: super::RandK,
}

impl UnbiasedCompressor for RandKLocal {
    fn name(&self) -> String {
        format!("randk(k={})", self.inner.k)
    }

    fn roundtrip(&self, g: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> usize {
        let mask = self.inner.draw(rng);
        let payload = mask.compress(g);
        mask.reconstruct_into(&payload, out);
        crate::transport::compressed_grad_len(
            payload.len(),
            super::codec::mask_wire_len(self.inner.d, self.inner.k),
        )
    }

    fn alpha(&self) -> f64 {
        self.inner.alpha()
    }
}

/// A validated, typed compressor specification — the single parse of the
/// config's `compressor` key, shared by the server-side algorithm, the
/// worker-side [`super::CompressorState`] and the TCP wire plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressorSpec {
    /// RandK with a worker-drawn (shipped) mask; k resolved from k_frac.
    RandK { k: usize },
    /// QSGD with s quantization levels (1 ≤ s ≤ 65535 — s travels as u16
    /// in the [`QuantBlock`] wire layout).
    Qsgd { s: u32 },
}

impl CompressorSpec {
    /// Parse `"randk"` (k from k_frac), `"qsgd"` / `"qsgd:<s>"`
    /// (default s = 4) at model dimension `d`.
    pub fn parse(spec: &str, d: usize, k_frac: f64) -> Result<Self, String> {
        let spec = spec.to_ascii_lowercase();
        let (base, arg) = match spec.split_once(':') {
            Some((b, a)) => (b, Some(a)),
            None => (spec.as_str(), None),
        };
        match base {
            "randk" => Ok(CompressorSpec::RandK {
                k: super::RandK::from_frac(d, k_frac).k,
            }),
            "qsgd" => {
                let s: u32 = arg.map_or(Ok(4), |a| {
                    a.parse().map_err(|_| format!("bad qsgd level '{a}'"))
                })?;
                if s == 0 || s > u16::MAX as u32 {
                    return Err(format!(
                        "qsgd levels s={s} outside 1..=65535 (s travels \
                         as u16 on the wire)"
                    ));
                }
                Ok(CompressorSpec::Qsgd { s })
            }
            other => Err(format!("unknown compressor '{other}'")),
        }
    }

    pub fn name(&self) -> String {
        match self {
            CompressorSpec::RandK { k } => format!("randk(k={k})"),
            CompressorSpec::Qsgd { s } => format!("qsgd(s={s})"),
        }
    }
}

/// Parse a compressor spec into the boxed [`UnbiasedCompressor`] form
/// (bench ablations; the training path uses [`CompressorSpec`] directly).
pub fn parse_spec(
    spec: &str,
    d: usize,
    k_frac: f64,
) -> Result<Box<dyn UnbiasedCompressor>, String> {
    Ok(match CompressorSpec::parse(spec, d, k_frac)? {
        CompressorSpec::RandK { k } => Box::new(RandKLocal {
            inner: super::RandK { d, k },
        }),
        CompressorSpec::Qsgd { s } => Box::new(Qsgd::new(d, s)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;

    fn vecs(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 1);
        let mut v = vec![0f32; d];
        rng.fill_gaussian(&mut v, 1.0);
        v
    }

    #[test]
    fn quantize_levels_in_range_and_signs_match() {
        let q = Qsgd::new(64, 4);
        let x = vecs(64, 1);
        let mut rng = Pcg64::new(2, 2);
        let (norm, levels) = q.quantize(&x, &mut rng);
        assert!(norm > 0.0);
        for (&l, &v) in levels.iter().zip(&x) {
            assert!(l.unsigned_abs() <= 4);
            if l != 0 {
                assert_eq!(l.signum(), if v < 0.0 { -1 } else { 1 });
            }
        }
    }

    #[test]
    fn qsgd_is_unbiased() {
        let d = 32;
        let q = Qsgd::new(d, 2);
        let x = vecs(d, 3);
        let mut rng = Pcg64::new(4, 4);
        let trials = 8000;
        let mut acc = vec![0f64; d];
        let mut out = vec![0f32; d];
        for _ in 0..trials {
            q.roundtrip(&x, &mut rng, &mut out);
            for (a, v) in acc.iter_mut().zip(&out) {
                *a += *v as f64;
            }
        }
        let norm = tensor::norm(&x);
        for i in 0..d {
            let mean = acc[i] / trials as f64;
            // per-coordinate MC se: level quantum is norm/s
            let se = norm / 2.0 / (trials as f64).sqrt();
            assert!(
                (mean - x[i] as f64).abs() < 6.0 * se,
                "coord {i}: {mean} vs {}",
                x[i]
            );
        }
    }

    #[test]
    fn qsgd_variance_within_omega_bound() {
        let d = 64;
        let q = Qsgd::new(d, 2);
        let x = vecs(d, 5);
        let x_norm_sq = tensor::norm_sq(&x);
        let mut rng = Pcg64::new(6, 6);
        let mut out = vec![0f32; d];
        let trials = 3000;
        let mut err = 0.0;
        for _ in 0..trials {
            q.roundtrip(&x, &mut rng, &mut out);
            err += tensor::dist_sq(&out, &x);
        }
        let mean_err = err / trials as f64;
        let bound = q.omega() * x_norm_sq;
        assert!(mean_err <= bound * 1.05, "{mean_err} vs {bound}");
    }

    #[test]
    fn zero_vector_roundtrips_exactly() {
        let q = Qsgd::new(16, 4);
        let mut rng = Pcg64::new(7, 7);
        let mut out = vec![1f32; 16];
        let bytes = q.roundtrip(&vec![0.0; 16], &mut rng, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
        assert_eq!(bytes, q.wire_bytes());
    }

    #[test]
    fn wire_bytes_beats_dense_for_small_s() {
        let q = Qsgd::new(11_809, 4); // 3 bits/level + 1 sign bit + norm
        let dense = 4 * 11_809;
        assert!(q.wire_bytes() * 5 < dense, "{} vs {dense}", q.wire_bytes());
        assert_eq!(q.level_bits(), 3);
    }

    #[test]
    fn parse_spec_variants() {
        assert!(parse_spec("randk", 100, 0.1).is_ok());
        assert!(parse_spec("qsgd", 100, 0.1).is_ok());
        let q = parse_spec("qsgd:8", 100, 0.1).unwrap();
        assert_eq!(q.name(), "qsgd(s=8)");
        assert!(parse_spec("zip", 100, 0.1).is_err());
    }

    #[test]
    fn compressor_spec_is_typed_and_bounded() {
        assert_eq!(
            CompressorSpec::parse("randk", 1000, 0.1).unwrap(),
            CompressorSpec::RandK { k: 100 }
        );
        assert_eq!(
            CompressorSpec::parse("qsgd", 100, 0.1).unwrap(),
            CompressorSpec::Qsgd { s: 4 }
        );
        assert_eq!(
            CompressorSpec::parse("QSGD:65535", 100, 0.1).unwrap(),
            CompressorSpec::Qsgd { s: 65535 }
        );
        // s must fit the u16 wire field and be >= 1
        assert!(CompressorSpec::parse("qsgd:0", 100, 0.1).is_err());
        assert!(CompressorSpec::parse("qsgd:65536", 100, 0.1).is_err());
        assert!(CompressorSpec::parse("qsgd:x", 100, 0.1).is_err());
    }

    #[test]
    fn quantize_into_matches_quantize_and_block() {
        let d = 96;
        let q = Qsgd::new(d, 4);
        let x = vecs(d, 13);
        let (n1, l1) = q.quantize(&x, &mut Pcg64::new(5, 5));
        let mut l2 = Vec::new();
        let n2 = q.quantize_into(&x, &mut Pcg64::new(5, 5), &mut l2);
        assert_eq!(n1, n2);
        assert_eq!(l1, l2);
        let block = q.quantize_block(&x, &mut Pcg64::new(5, 5));
        assert_eq!(block.s, 4);
        assert_eq!(block.norm, n1);
        assert_eq!(block.levels, l1);
        let mut buf = Vec::new();
        block.encode_body_into(&mut buf);
        // the byte model: header + exactly this packed body
        assert_eq!(
            q.wire_bytes(),
            crate::transport::HEADER_BYTES + buf.len()
        );
    }

    #[test]
    fn randk_local_roundtrip_support() {
        let c = RandKLocal {
            inner: crate::compression::RandK { d: 50, k: 5 },
        };
        let mut rng = Pcg64::new(8, 8);
        let g = vecs(50, 9);
        let mut out = vec![0f32; 50];
        let bytes = c.roundtrip(&g, &mut rng, &mut out);
        assert_eq!(out.iter().filter(|v| **v != 0.0).count(), 5);
        assert!(bytes < 4 * 50);
        assert!((c.alpha() - 10.0).abs() < 1e-9);
    }
}
