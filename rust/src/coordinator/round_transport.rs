//! How a round's gradients are exchanged — in-process or over sockets.
//!
//! [`Trainer::step`][super::Trainer::step] drives a [`RoundTransport`]:
//! given the current parameters, fill `grad_store`/`loss_store` with this
//! round's per-worker contributions. Two implementations exist:
//!
//! * [`LocalTransport`] — the persistent worker pool (or the sequential
//!   engine under PJRT). This is the tested oracle: every other transport
//!   must reproduce its `RunReport` bit for bit on the same config/seed.
//! * [`TcpTransport`] — the coordinator side of the socket runtime
//!   (`transport = "tcp"`): broadcast the model through
//!   [`CoordinatorServer`], collect worker uplinks in wire format, and
//!   reconstruct the gradient buffers the algorithm layer expects.
//!
//! ## Wire plans and byte parity
//!
//! The simulation's [`ByteMeter`][crate::transport::ByteMeter] *models*
//! per-round traffic; the TCP path must *transmit* exactly those bytes.
//! That works when the uplink payload alone lets the server rebuild the
//! algorithm's input:
//!
//! * [`WirePlan::SparseGlobal`] (RoSDHB, k < d) — downlink
//!   `ModelBroadcast` with the mask seed; workers re-derive the shared
//!   mask, uplink `CompressedGrad` with the k masked gradient values.
//!   The server scatters them into a d-buffer (zeros elsewhere); the
//!   algorithm's own `mask.compress` then recovers the identical payload,
//!   so results match the local transport bitwise.
//! * [`WirePlan::Dense`] (RoSDHB at k = d, robust-dgd, dgd) — plain
//!   broadcast down, `FullGrad` up.
//!
//! Payload-attack Byzantine workers join as *drones*: the omniscient
//! adversary of the paper is still simulated server-side (that is what
//! keeps runs reproducible), but each drone receives the broadcast and
//! ships a correctly-sized placeholder uplink so measured socket traffic
//! matches the accounting model. Crash-fault Byzantine workers
//! (`attack = "none"`, f > 0) stay silent, exactly like the simulation.
//!
//! A worker that misses the round deadline, crashes, or violates the
//! protocol degrades into a dropped contribution (zero gradient, zero
//! loss, eviction from later rounds) — never a hang.

use crate::compression::{mask_from_seed, Mask, RandK};
use crate::config::ExperimentConfig;
use crate::transport::net::{CoordinatorServer, NetStats};
use crate::transport::WireMessage;
use crate::worker::{GradEngine, HonestWorker};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Duration;

use super::pool::{Job, WorkerPool};

/// How long a coordinator waits for all workers to join.
pub const RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(300);

/// Pull a worker out of its slot, or report a poisoned transport: slots
/// are only left empty when the pool died mid-round and took the
/// in-flight workers with it. Returning `Err` here keeps the "failures
/// surface as `Err`, never an abort" contract even on calls *after* such
/// a failure.
fn take_worker(
    workers: &mut [Option<HonestWorker>],
    slot: usize,
) -> Result<HonestWorker> {
    workers[slot].take().ok_or_else(|| {
        anyhow!(
            "trainer poisoned: worker {slot} was lost in a failed round \
             (worker pool died); rebuild the Trainer"
        )
    })
}

/// One round-trip of the synchronous round loop: distribute `params`,
/// collect per-worker gradient contributions.
pub trait RoundTransport: Send {
    fn name(&self) -> &'static str;

    /// Fill `grad_store[w]` / `loss_store[w]` for every gradient slot
    /// (honest workers first, then data-level Byzantine workers). `engine`
    /// is the trainer's sequential gradient engine — used only by the
    /// local transport when no pool is available (PJRT).
    fn exchange(
        &mut self,
        t: u64,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        grad_store: &mut [Vec<f32>],
        loss_store: &mut [f32],
    ) -> Result<()>;

    /// Fresh honest full-d gradients at `params` for (G,B) estimation —
    /// requires direct worker access, so only the local transport can.
    fn probe_honest(
        &mut self,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        n_honest: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// Measured socket traffic, if this transport moves real bytes.
    fn net_stats(&self) -> Option<NetStats> {
        None
    }

    /// Release transport resources (TCP: send `BYE` to all workers).
    /// Also runs on drop; explicit calls make shutdown ordering testable.
    fn shutdown(&mut self) {}

    /// Diagnostic/test hook into the in-process implementation.
    fn as_local_mut(&mut self) -> Option<&mut LocalTransport> {
        None
    }
}

// ------------------------------------------------------------------ local

/// In-process gradient exchange over the persistent [`WorkerPool`] (the
/// pre-socket behavior of `Trainer`, unchanged results).
pub struct LocalTransport {
    /// Gradient workers: honest in slots `[0, n_honest)`, then data-level
    /// Byzantine workers. `None` only while a worker is in flight inside
    /// the pool.
    pub(crate) workers: Vec<Option<HonestWorker>>,
    /// Persistent gradient pool (native engine only; `None` under PJRT —
    /// sequential there, identical numerics).
    pub(crate) pool: Option<WorkerPool>,
    /// Broadcast parameter buffer shared with pool threads; refreshed in
    /// place each round (no allocation once every job handle is returned).
    shared_params: Arc<Vec<f32>>,
}

impl LocalTransport {
    pub fn new(workers: Vec<HonestWorker>, pool: Option<WorkerPool>) -> Self {
        LocalTransport {
            workers: workers.into_iter().map(Some).collect(),
            pool,
            shared_params: Arc::new(Vec::new()),
        }
    }

    pub fn n_slots(&self) -> usize {
        self.workers.len()
    }
}

impl RoundTransport for LocalTransport {
    fn name(&self) -> &'static str {
        "local"
    }

    fn exchange(
        &mut self,
        _t: u64,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        grad_store: &mut [Vec<f32>],
        loss_store: &mut [f32],
    ) -> Result<()> {
        let n_grad = self.workers.len();
        debug_assert_eq!(grad_store.len(), n_grad);
        if let Some(pool) = &self.pool {
            // Refresh the shared broadcast buffer in place; all job
            // handles from the previous round have been returned, so the
            // Arc is unique and this is a copy, not an allocation. (A
            // non-unique Arc can only mean a previous round failed midway
            // and leaked a handle — fall back to a fresh buffer then.)
            if Arc::get_mut(&mut self.shared_params).is_none() {
                self.shared_params = Arc::new(Vec::new());
            }
            let buf = Arc::get_mut(&mut self.shared_params)
                .expect("freshly replaced Arc is unique");
            buf.resize(params.len(), 0.0);
            buf.copy_from_slice(params);
            for slot in 0..n_grad {
                let worker = take_worker(&mut self.workers, slot)?;
                let buf = std::mem::take(&mut grad_store[slot]);
                pool.submit(Job {
                    slot,
                    worker,
                    params: Arc::clone(&self.shared_params),
                    batch,
                    buf,
                })?;
            }
            let mut first_err: Option<anyhow::Error> = None;
            for _ in 0..n_grad {
                let done = pool.recv()?;
                self.workers[done.slot] = Some(done.worker);
                grad_store[done.slot] = done.buf;
                match done.loss {
                    Ok(l) => loss_store[done.slot] = l,
                    Err(e) => {
                        if first_err.is_none() {
                            first_err =
                                Some(anyhow!("worker {}: {e}", done.slot));
                        }
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
        } else {
            for slot in 0..n_grad {
                let mut worker = take_worker(&mut self.workers, slot)?;
                let res = worker.compute_grad_into(
                    engine,
                    params,
                    batch,
                    &mut grad_store[slot],
                );
                self.workers[slot] = Some(worker);
                loss_store[slot] = res?;
            }
        }
        Ok(())
    }

    fn probe_honest(
        &mut self,
        engine: &mut dyn GradEngine,
        params: &[f32],
        batch: usize,
        n_honest: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(n_honest);
        for slot in 0..n_honest {
            let mut worker = take_worker(&mut self.workers, slot)?;
            let mut buf = vec![0f32; params.len()];
            let res = worker.compute_grad_into(engine, params, batch, &mut buf);
            self.workers[slot] = Some(worker);
            res?;
            out.push(buf);
        }
        Ok(out)
    }

    fn as_local_mut(&mut self) -> Option<&mut LocalTransport> {
        Some(self)
    }
}

// -------------------------------------------------------------------- tcp

/// Which messages travel each round (derived from algorithm + k).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WirePlan {
    /// Coordinated-mask RoSDHB: `ModelBroadcast` (+seed) down,
    /// k-value `CompressedGrad` up.
    SparseGlobal { k: usize },
    /// Dense algorithms (and k = d): plain broadcast down, `FullGrad` up.
    Dense,
}

impl WirePlan {
    /// The plan implied by a validated config at model dimension `d`.
    pub fn from_config(cfg: &ExperimentConfig, d: usize) -> WirePlan {
        let k = RandK::from_frac(d, cfg.k_frac).k;
        match cfg.algorithm {
            crate::config::Algorithm::RoSdhb if k < d => {
                WirePlan::SparseGlobal { k }
            }
            _ => WirePlan::Dense,
        }
    }
}

/// Coordinator side of `transport = "tcp"`.
pub struct TcpTransport {
    server: CoordinatorServer,
    plan: WirePlan,
    d: usize,
    seed: u64,
    /// Gradient slots (honest + data-level Byzantine) — mirrors the
    /// trainer's `grad_store` layout.
    n_grad: usize,
    /// Payload-attack drones reply with placeholder uplinks; crash-fault
    /// Byzantine slots stay silent.
    drones_reply: bool,
    timeout: Duration,
}

impl TcpTransport {
    /// Wait for all `n_total` workers to join `server`, then build the
    /// transport. `d` is the model dimension of the trainer's engine.
    pub fn rendezvous(
        mut server: CoordinatorServer,
        cfg: &ExperimentConfig,
        d: usize,
    ) -> Result<Self> {
        let attack =
            crate::attacks::parse_spec(&cfg.attack).map_err(|e| anyhow!(e))?;
        let (n_grad, drones_reply) = match attack {
            crate::attacks::AttackKind::LabelFlip => (cfg.n_total(), false),
            crate::attacks::AttackKind::None => (cfg.n_honest, false),
            crate::attacks::AttackKind::Payload(_) => (cfg.n_honest, true),
        };
        server.rendezvous(
            cfg.n_total(),
            cfg.wire_fingerprint(),
            RENDEZVOUS_TIMEOUT,
        )?;
        Ok(TcpTransport {
            server,
            plan: WirePlan::from_config(cfg, d),
            d,
            seed: cfg.seed,
            n_grad,
            drones_reply,
            timeout: Duration::from_millis(cfg.round_timeout_ms.max(1)),
        })
    }

    /// Validate and scatter one worker uplink into its gradient slot.
    fn apply_uplink(
        &self,
        t: u64,
        bytes: &[u8],
        mask: Option<&Mask>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let msg = WireMessage::decode(bytes, self.d)
            .map_err(|e| anyhow!("undecodable uplink: {e}"))?;
        match msg {
            WireMessage::CompressedGrad {
                round,
                values,
                mask: wire_mask,
                ..
            } => {
                let m = mask.ok_or_else(|| {
                    anyhow!("CompressedGrad under a dense wire plan")
                })?;
                if wire_mask.is_some() {
                    return Err(anyhow!(
                        "per-worker masks are not part of the tcp wire plan"
                    ));
                }
                if round != t {
                    return Err(anyhow!("round {round} != current {t}"));
                }
                if values.len() != m.k() {
                    return Err(anyhow!(
                        "payload {} values != k {}",
                        values.len(),
                        m.k()
                    ));
                }
                // Scatter the raw payload (no α): the algorithm re-gathers
                // these exact values via `mask.compress`, making the TCP
                // round bit-identical to the in-process round.
                out.resize(self.d, 0.0);
                out.fill(0.0);
                for (&ci, &v) in m.idx.iter().zip(&values) {
                    out[ci as usize] = v;
                }
                Ok(())
            }
            WireMessage::FullGrad { round, values, .. } => {
                if mask.is_some() {
                    return Err(anyhow!(
                        "FullGrad under the sparse wire plan"
                    ));
                }
                if round != t {
                    return Err(anyhow!("round {round} != current {t}"));
                }
                if values.len() != self.d {
                    return Err(anyhow!(
                        "dense gradient has {} values, model has {}",
                        values.len(),
                        self.d
                    ));
                }
                out.clear();
                out.extend_from_slice(&values);
                Ok(())
            }
            other => Err(anyhow!("unexpected uplink message: {other:?}")),
        }
    }
}

impl RoundTransport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn exchange(
        &mut self,
        t: u64,
        _engine: &mut dyn GradEngine,
        params: &[f32],
        _batch: usize,
        grad_store: &mut [Vec<f32>],
        loss_store: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(grad_store.len(), self.n_grad);
        let (msg, mask) = match self.plan {
            WirePlan::SparseGlobal { k } => {
                let mask_seed = RandK::round_seed(self.seed, t);
                (
                    WireMessage::ModelBroadcast {
                        round: t,
                        params: params.to_vec(),
                        mask_seed,
                    },
                    Some(mask_from_seed(mask_seed, self.d, k)),
                )
            }
            WirePlan::Dense => (
                WireMessage::ModelBroadcastPlain {
                    round: t,
                    params: params.to_vec(),
                },
                None,
            ),
        };
        let n_conn = self.server.n_workers();
        let mut expect = vec![false; n_conn];
        for e in expect.iter_mut().take(self.n_grad) {
            *e = true;
        }
        if self.drones_reply {
            for e in expect.iter_mut().skip(self.n_grad) {
                *e = true;
            }
        }
        let n_expected = self.server.broadcast(t, &msg, &expect, self.timeout);
        if self.server.n_alive() == 0 {
            return Err(anyhow!(
                "all {n_conn} workers are gone — nothing left to train with"
            ));
        }
        let mut got = vec![false; self.n_grad];
        for reply in self.server.collect(n_expected, t, self.timeout) {
            let w = reply.worker as usize;
            match reply.result {
                Ok((loss, bytes)) => {
                    if w >= self.n_grad {
                        continue; // drone placeholder: metered, ignored
                    }
                    match self.apply_uplink(t, &bytes, mask.as_ref(), &mut grad_store[w])
                    {
                        Ok(()) => {
                            loss_store[w] = loss;
                            got[w] = true;
                        }
                        Err(e) => eprintln!(
                            "rosdhb[tcp]: round {t}: worker {w}: {e} — \
                             contribution dropped"
                        ),
                    }
                }
                Err(e) => {
                    eprintln!("rosdhb[tcp]: round {t}: worker {w}: {e}")
                }
            }
        }
        // Stalled / crashed / malformed workers degrade into a zero
        // contribution for this round (and eviction for later ones when
        // the connection is gone) — the run keeps moving.
        for (w, ok) in got.iter().enumerate() {
            if !*ok {
                let g = &mut grad_store[w];
                g.resize(self.d, 0.0);
                g.fill(0.0);
                loss_store[w] = 0.0;
                eprintln!(
                    "rosdhb[tcp]: round {t}: worker {w} contributed nothing — \
                     zero gradient substituted"
                );
            }
        }
        Ok(())
    }

    fn probe_honest(
        &mut self,
        _engine: &mut dyn GradEngine,
        _params: &[f32],
        _batch: usize,
        _n_honest: usize,
    ) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!(
            "(G,B) probing needs direct worker access — run it under \
             transport = \"local\""
        ))
    }

    fn net_stats(&self) -> Option<NetStats> {
        Some(self.server.stats())
    }

    fn shutdown(&mut self) {
        self.server.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    #[test]
    fn wire_plan_tracks_algorithm_and_k() {
        let mut cfg = ExperimentConfig::default_mnist_like();
        cfg.k_frac = 0.1;
        assert_eq!(
            WirePlan::from_config(&cfg, 1000),
            WirePlan::SparseGlobal { k: 100 }
        );
        cfg.k_frac = 1.0;
        assert_eq!(WirePlan::from_config(&cfg, 1000), WirePlan::Dense);
        cfg.k_frac = 0.1;
        cfg.algorithm = Algorithm::RobustDgd;
        assert_eq!(WirePlan::from_config(&cfg, 1000), WirePlan::Dense);
    }
}
