#!/usr/bin/env python3
"""Merge rosdhb per-process trace journals into one timeline (stdlib only).

Usage:
    python3 scripts/merge_trace.py TRACE.jsonl [TRACE.jsonl.w0 ...] \
        [--out merged.jsonl]

A traced run writes one journal per process: the coordinator's at
``trace_path`` and each worker's at ``trace_path.w<id>``. Every journal
stamps events with ``ts_us`` measured from its *own* process start — the
files cannot be interleaved by raw timestamp. This tool rebases each
worker journal onto the coordinator clock and emits one sorted stream.

Two generations of worker journal exist:

* **Natively aligned** (the side-channel clock probe, PR 10): the
  journal carries at least one ``clock_sync`` event, meaning the worker
  measured its offset against the coordinator's journal clock over the
  status listener and stamped ``ts_us`` in coordinator time itself.
  These timestamps are used *as-is*; the admit anchor below degrades to
  a drift validator (the journal's first event must land within
  ``--drift-bound-us`` of its admit mark, else the merge fails loudly).
* **Legacy** (no ``clock_sync``): timestamps are measured from the
  worker's own process start and are rebased on the WELCOME anchor — a
  worker opens its journal immediately after rendezvous assigns its id,
  which is the same instant the coordinator journals
  ``rendezvous_admit`` for that slot. So worker ``w``'s local zero maps
  to the coordinator-time ``ts_us`` of the first ``rendezvous_admit``
  naming slot ``w``, and every worker event lands at
  ``admit_ts + local_ts``.

Worker journals are auto-discovered next to the coordinator journal
(``TRACE.jsonl.w*``) when not listed explicitly. Each merged line keeps
the original event keys, rewrites ``ts_us`` to coordinator time, and
adds ``src`` ("coord" or "w<id>") plus ``ts_local_us`` (the original
stamp). The merge fails loudly on malformed JSONL, a worker journal with
no admit anchor, or a lost line (output count must equal the sum of
input counts).
"""

import argparse
import glob
import json
import sys


def fail(msg):
    print(f"merge_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load_journal(path):
    events = []
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                fail(f"{path}:{lineno}: blank line in JSONL journal")
            try:
                ev = json.loads(line)
            except ValueError as e:
                fail(f"{path}:{lineno}: not JSON: {e}")
            if not isinstance(ev, dict):
                fail(f"{path}:{lineno}: not an object")
            if not isinstance(ev.get("ts_us"), (int, float)):
                fail(f"{path}:{lineno}: missing numeric ts_us")
            events.append(ev)
    return events


def worker_id(path):
    """The <id> of a ``...jsonl.w<id>`` journal, or None."""
    suffix = path.rsplit(".", 1)[-1]
    if suffix.startswith("w") and suffix[1:].isdigit():
        return int(suffix[1:])
    return None


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "traces",
        nargs="+",
        help="coordinator journal first, then worker journals "
        "(auto-discovered as <coordinator>.w* when omitted)",
    )
    ap.add_argument(
        "--out", help="write merged JSONL here instead of stdout"
    )
    ap.add_argument(
        "--drift-bound-us",
        type=int,
        default=10_000_000,
        help="natively aligned journals (those carrying clock_sync "
        "events) must start within this many microseconds of their "
        "admit anchor (default 10s)",
    )
    args = ap.parse_args()

    coord_path = args.traces[0]
    worker_paths = args.traces[1:]
    if not worker_paths:
        worker_paths = sorted(
            glob.glob(glob.escape(coord_path) + ".w*"), key=worker_id
        )
    for p in worker_paths:
        if worker_id(p) is None:
            fail(f"{p}: worker journals must be named <trace>.w<id>")

    coord = load_journal(coord_path)
    # WELCOME anchor: first admit per slot (a readmitted slot keeps its
    # original anchor — later journals from the same id would overwrite
    # the file anyway, so only one origin per id can exist).
    admits = {}
    for ev in coord:
        if ev.get("event") == "rendezvous_admit":
            admits.setdefault(int(ev["worker"]), int(ev["ts_us"]))

    merged = []
    for ev in coord:
        ev = dict(ev)
        ev["src"] = "coord"
        ev["ts_local_us"] = ev["ts_us"]
        merged.append(ev)
    n_inputs = len(coord)
    n_aligned = 0
    for path in worker_paths:
        wid = worker_id(path)
        if wid not in admits:
            fail(
                f"{path}: no rendezvous_admit for slot {wid} in "
                f"{coord_path} — cannot anchor this journal"
            )
        offset = admits[wid]
        events = load_journal(path)
        n_inputs += len(events)
        aligned = any(ev.get("event") == "clock_sync" for ev in events)
        n_aligned += aligned
        if aligned:
            # natively aligned journal: timestamps are already
            # coordinator time; the anchor only validates drift
            drift = int(events[0]["ts_us"]) - offset
            if abs(drift) > args.drift_bound_us:
                fail(
                    f"{path}: aligned journal starts {drift}us from its "
                    f"admit anchor (bound {args.drift_bound_us}us) — "
                    "clock alignment is broken"
                )
        for ev in events:
            ev = dict(ev)
            ev["src"] = f"w{wid}"
            ev["ts_local_us"] = ev["ts_us"]
            if not aligned:
                ev["ts_us"] = int(ev["ts_us"]) + offset
            merged.append(ev)

    # Stable sort: same-timestamp events keep coordinator-first,
    # then-ascending-worker input order.
    merged.sort(key=lambda ev: ev["ts_us"])
    if len(merged) != n_inputs:
        fail(f"merged {len(merged)} events from {n_inputs} input lines")

    out = open(args.out, "w", encoding="utf-8") if args.out else sys.stdout
    try:
        for ev in merged:
            out.write(json.dumps(ev, sort_keys=True))
            out.write("\n")
    finally:
        if args.out:
            out.close()
    print(
        f"merge_trace: OK ({len(merged)} events from 1 coordinator + "
        f"{len(worker_paths)} worker journals, {n_aligned} natively "
        "aligned)",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
