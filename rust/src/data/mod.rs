//! Datasets: the MNIST-like synthetic task plus a real-MNIST IDX loader.
//!
//! The paper evaluates on MNIST (60k train / 10k test, 10 classes). This
//! environment has no network access, so the default task is a calibrated
//! synthetic stand-in (DESIGN.md §1): 10 class prototypes on a 14×14 grid,
//! sampled with per-image translation jitter and pixel noise — learnable to
//! ≳90% by the 11.8k-param model within a few hundred GD rounds, which is
//! the regime the paper's τ=0.85 communication-cost experiment probes.
//! If real MNIST IDX files are present, [`load_mnist_idx`] is preferred
//! (28×28 inputs are 2×2-average-pooled down to 14×14).

pub mod partition;
pub mod synthetic_images;

pub use partition::{partition_dirichlet, partition_iid, Shard};
pub use synthetic_images::generate as generate_synthetic;
pub use synthetic_images::generate_split as generate_synthetic_split;

use crate::prng::Pcg64;

/// Image side of the model input grid (D_IN = SIDE²  = 196).
pub const SIDE: usize = 14;
/// Flattened input dimension; must match `artifacts/meta.json: d_in`.
pub const D_IN: usize = SIDE * SIDE;
/// Number of classes.
pub const CLASSES: usize = 10;

/// A dense dataset of flat f32 images + byte labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major `[n, D_IN]`.
    pub images: Vec<f32>,
    /// `[n]`, values in `0..CLASSES`.
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * D_IN..(i + 1) * D_IN]
    }

    /// Gather rows by index into a new dataset (used by partitioning).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut images = Vec::with_capacity(idx.len() * D_IN);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            images.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        Dataset { images, labels }
    }

    /// Sample a batch of `b` examples (with replacement across rounds,
    /// without replacement within a batch) into `(x, y_onehot)` buffers
    /// shaped for the grad artifact: x `[b, D_IN]`, y `[b, CLASSES]`.
    pub fn sample_batch(
        &self,
        rng: &mut Pcg64,
        b: usize,
        x: &mut Vec<f32>,
        y1h: &mut Vec<f32>,
    ) {
        assert!(!self.is_empty());
        x.clear();
        y1h.clear();
        x.reserve(b * D_IN);
        y1h.resize(b * CLASSES, 0.0);
        y1h.fill(0.0);
        if b >= self.len() {
            // full-batch: deterministic order (plus wraparound repeat)
            for i in 0..b {
                let j = i % self.len();
                x.extend_from_slice(self.image(j));
                y1h[i * CLASSES + self.labels[j] as usize] = 1.0;
            }
            return;
        }
        let picks = rng.sample_k_of(self.len(), b);
        for (i, &j) in picks.iter().enumerate() {
            x.extend_from_slice(self.image(j as usize));
            y1h[i * CLASSES + self.labels[j as usize] as usize] = 1.0;
        }
    }

    /// Class histogram (for partition-skew tests).
    pub fn class_counts(&self) -> [usize; CLASSES] {
        let mut c = [0usize; CLASSES];
        for &l in &self.labels {
            c[l as usize] += 1;
        }
        c
    }
}

/// Load MNIST from IDX files (`train-images-idx3-ubyte` etc.) in `dir`,
/// average-pooling 28×28 → 14×14 and scaling to [0, 1].
pub fn load_mnist_idx(dir: &str) -> Result<(Dataset, Dataset), String> {
    let train = load_split(dir, "train-images-idx3-ubyte", "train-labels-idx1-ubyte")?;
    let test = load_split(dir, "t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")?;
    Ok((train, test))
}

fn load_split(dir: &str, img: &str, lab: &str) -> Result<Dataset, String> {
    let ib = std::fs::read(format!("{dir}/{img}"))
        .map_err(|e| format!("{dir}/{img}: {e}"))?;
    let lb = std::fs::read(format!("{dir}/{lab}"))
        .map_err(|e| format!("{dir}/{lab}: {e}"))?;
    parse_idx_pair(&ib, &lb)
}

fn be32(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an IDX3 image file + IDX1 label file into a downsampled Dataset.
pub fn parse_idx_pair(ib: &[u8], lb: &[u8]) -> Result<Dataset, String> {
    if ib.len() < 16 || be32(ib, 0) != 0x0000_0803 {
        return Err("bad idx3 magic".into());
    }
    if lb.len() < 8 || be32(lb, 0) != 0x0000_0801 {
        return Err("bad idx1 magic".into());
    }
    let n = be32(ib, 4) as usize;
    let rows = be32(ib, 8) as usize;
    let cols = be32(ib, 12) as usize;
    if rows != 28 || cols != 28 {
        return Err(format!("want 28x28 MNIST, got {rows}x{cols}"));
    }
    if be32(lb, 4) as usize != n {
        return Err("image/label count mismatch".into());
    }
    if ib.len() < 16 + n * rows * cols || lb.len() < 8 + n {
        return Err("truncated idx payload".into());
    }
    let mut images = Vec::with_capacity(n * D_IN);
    for i in 0..n {
        let base = 16 + i * rows * cols;
        for r in 0..SIDE {
            for c in 0..SIDE {
                // 2x2 average pool
                let mut acc = 0u32;
                for dr in 0..2 {
                    for dc in 0..2 {
                        acc += ib[base + (2 * r + dr) * cols + 2 * c + dc]
                            as u32;
                    }
                }
                images.push(acc as f32 / (4.0 * 255.0));
            }
        }
    }
    let labels = lb[8..8 + n].to_vec();
    if labels.iter().any(|&l| l >= CLASSES as u8) {
        return Err("label out of range".into());
    }
    Ok(Dataset { images, labels })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_idx(n: usize) -> (Vec<u8>, Vec<u8>) {
        let mut ib = Vec::new();
        ib.extend_from_slice(&0x0803u32.to_be_bytes());
        ib.extend_from_slice(&(n as u32).to_be_bytes());
        ib.extend_from_slice(&28u32.to_be_bytes());
        ib.extend_from_slice(&28u32.to_be_bytes());
        for i in 0..n {
            ib.extend(std::iter::repeat((i * 7 % 256) as u8).take(28 * 28));
        }
        let mut lb = Vec::new();
        lb.extend_from_slice(&0x0801u32.to_be_bytes());
        lb.extend_from_slice(&(n as u32).to_be_bytes());
        lb.extend((0..n).map(|i| (i % 10) as u8));
        (ib, lb)
    }

    #[test]
    fn idx_roundtrip_and_pooling() {
        let (ib, lb) = tiny_idx(5);
        let ds = parse_idx_pair(&ib, &lb).unwrap();
        assert_eq!(ds.len(), 5);
        assert_eq!(ds.image(0).len(), D_IN);
        // constant image -> constant pooled value v/255
        let v = ds.image(3)[0];
        assert!((v - (3.0 * 7.0) / 255.0).abs() < 1e-6);
        assert!(ds.image(3).iter().all(|&p| (p - v).abs() < 1e-6));
        assert_eq!(ds.labels, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn idx_rejects_corrupt() {
        let (ib, lb) = tiny_idx(3);
        assert!(parse_idx_pair(&ib[..10], &lb).is_err());
        let mut bad = ib.clone();
        bad[3] = 0x99; // wrong magic
        assert!(parse_idx_pair(&bad, &lb).is_err());
        assert!(parse_idx_pair(&ib, &lb[..8]).is_err());
    }

    #[test]
    fn batch_shapes_and_onehot() {
        let ds = generate_synthetic(7, 200);
        let mut rng = Pcg64::new(1, 1);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        ds.sample_batch(&mut rng, 60, &mut x, &mut y);
        assert_eq!(x.len(), 60 * D_IN);
        assert_eq!(y.len(), 60 * CLASSES);
        for row in y.chunks(CLASSES) {
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(row.iter().filter(|&&v| v == 0.0).count(), 9);
        }
    }

    #[test]
    fn full_batch_when_b_exceeds_len() {
        let ds = generate_synthetic(7, 10);
        let mut rng = Pcg64::new(1, 1);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        ds.sample_batch(&mut rng, 20, &mut x, &mut y);
        assert_eq!(x.len(), 20 * D_IN);
        // wraps deterministically
        assert_eq!(&x[..D_IN], &x[10 * D_IN..11 * D_IN]);
        let _ = y;
    }

    #[test]
    fn subset_gathers() {
        let ds = generate_synthetic(7, 50);
        let sub = ds.subset(&[3, 7, 7]);
        assert_eq!(sub.len(), 3);
        assert_eq!(sub.labels[1], ds.labels[7]);
        assert_eq!(sub.labels[2], ds.labels[7]);
        assert_eq!(sub.image(0), ds.image(3));
    }
}
