//! Minimal readiness poller for the event-loop transport.
//!
//! One [`Poller`] watches many nonblocking sockets for *read*
//! readiness. On x86-64 Linux it is a thin wrapper over raw `epoll`
//! syscalls (no external crate — the workspace deliberately has no
//! async/net dependencies); everywhere else it degrades to a polite
//! scan loop that reports every registered token as ready after a
//! short sleep, which is correct (callers must handle `WouldBlock`
//! anyway — readiness is only ever a hint) if less efficient.
//!
//! Write readiness is intentionally *not* part of the interface: a
//! level-triggered `EPOLLOUT` registration on a mostly-idle socket
//! would wake the loop continuously. The event-loop server instead
//! pumps its write queues opportunistically and sleeps briefly on
//! `WouldBlock`, which is simpler and fits the strict
//! broadcast-then-collect round structure.

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Readiness poller over raw fds. See the module docs.
pub struct Poller {
    imp: Imp,
}

enum Imp {
    #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
    Epoll { epfd: i32, events: Vec<EpollEvent> },
    /// Portable fallback: no kernel help — report everything ready.
    Scan { tokens: Vec<(RawFd, usize)> },
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod sys {
    use super::EpollEvent;
    use std::arch::asm;

    pub const EPOLL_CLOEXEC: u64 = 0x80000;
    pub const EPOLL_CTL_ADD: u64 = 1;
    pub const EPOLL_CTL_DEL: u64 = 2;
    pub const EPOLLIN: u32 = 0x1;
    const SYS_CLOSE: u64 = 3;
    const SYS_EPOLL_WAIT: u64 = 232;
    const SYS_EPOLL_CTL: u64 = 233;
    const SYS_EPOLL_CREATE1: u64 = 291;
    const EINTR: isize = -4;

    /// Raw 4-argument syscall. Returns the kernel's raw result
    /// (negative = -errno).
    ///
    /// # Safety
    /// `nr` and its arguments must form a valid syscall: pointers must
    /// point to live memory of the kernel-expected shape for the call.
    unsafe fn syscall4(nr: u64, a1: u64, a2: u64, a3: u64, a4: u64) -> isize {
        let ret: isize;
        asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    fn check(ret: isize) -> std::io::Result<isize> {
        if ret < 0 {
            Err(std::io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret)
        }
    }

    pub fn epoll_create1() -> std::io::Result<i32> {
        // SAFETY: epoll_create1 takes one integer flag; no pointers.
        check(unsafe { syscall4(SYS_EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0) })
            .map(|fd| fd as i32)
    }

    pub fn epoll_ctl(
        epfd: i32,
        op: u64,
        fd: i32,
        ev: Option<&mut EpollEvent>,
    ) -> std::io::Result<()> {
        let evp = ev.map_or(0u64, |e| e as *mut EpollEvent as u64);
        // SAFETY: `evp` is either null (allowed for DEL) or points to a
        // live, writable EpollEvent of the exact layout epoll_ctl wants.
        check(unsafe { syscall4(SYS_EPOLL_CTL, epfd as u64, op, fd as u64, evp) })
            .map(|_| ())
    }

    pub fn epoll_wait(
        epfd: i32,
        events: &mut [EpollEvent],
        timeout_ms: i32,
    ) -> std::io::Result<usize> {
        loop {
            // SAFETY: `events` is a live mutable slice of EpollEvent;
            // the length passed bounds the kernel's writes into it.
            let ret = unsafe {
                syscall4(
                    SYS_EPOLL_WAIT,
                    epfd as u64,
                    events.as_mut_ptr() as u64,
                    events.len() as u64,
                    timeout_ms as u64,
                )
            };
            if ret == EINTR {
                continue;
            }
            return check(ret).map(|n| n as usize);
        }
    }

    pub fn close(fd: i32) {
        // SAFETY: closing an owned fd; errors are ignorable here.
        let _ = unsafe { syscall4(SYS_CLOSE, fd as u64, 0, 0, 0) };
    }
}

impl Poller {
    /// New poller; falls back to the scan implementation if epoll is
    /// unavailable.
    pub fn new() -> io::Result<Self> {
        #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
        if let Ok(epfd) = sys::epoll_create1() {
            return Ok(Poller {
                imp: Imp::Epoll {
                    epfd,
                    events: vec![EpollEvent { events: 0, data: 0 }; 128],
                },
            });
        }
        Ok(Poller {
            imp: Imp::Scan { tokens: Vec::new() },
        })
    }

    /// Watch `fd` for read readiness; `token` comes back from
    /// [`Self::wait`]. One registration per fd.
    pub fn register(&mut self, fd: RawFd, token: usize) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
            Imp::Epoll { epfd, .. } => {
                let mut ev = EpollEvent {
                    events: sys::EPOLLIN,
                    data: token as u64,
                };
                sys::epoll_ctl(*epfd, sys::EPOLL_CTL_ADD, fd, Some(&mut ev))
            }
            Imp::Scan { tokens } => {
                tokens.push((fd, token));
                Ok(())
            }
        }
    }

    /// Stop watching `fd`. Call *before* the fd is closed.
    pub fn deregister(&mut self, fd: RawFd, token: usize) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
            Imp::Epoll { epfd, .. } => {
                let _ = token;
                sys::epoll_ctl(*epfd, sys::EPOLL_CTL_DEL, fd, None)
            }
            Imp::Scan { tokens } => {
                tokens.retain(|&(f, t)| !(f == fd && t == token));
                Ok(())
            }
        }
    }

    /// Block up to `timeout` and append the tokens of read-ready fds
    /// to `ready` (cleared first). Level-triggered: an fd with
    /// unconsumed data reports ready again on the next call. The scan
    /// fallback reports *all* registered tokens after a short sleep —
    /// a correct over-approximation since callers treat readiness as a
    /// hint and handle `WouldBlock`.
    pub fn wait(
        &mut self,
        timeout: Duration,
        ready: &mut Vec<usize>,
    ) -> io::Result<()> {
        ready.clear();
        match &mut self.imp {
            #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
            Imp::Epoll { epfd, events } => {
                let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
                let n = sys::epoll_wait(*epfd, events, ms)?;
                for ev in events.iter().take(n) {
                    // packed struct: copy the field out by value; a
                    // reference into a packed field is UB.
                    let data = ev.data;
                    ready.push(data as usize);
                }
                Ok(())
            }
            Imp::Scan { tokens } => {
                if !timeout.is_zero() {
                    std::thread::sleep(timeout.min(Duration::from_millis(1)));
                }
                ready.extend(tokens.iter().map(|&(_, t)| t));
                Ok(())
            }
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
        if let Imp::Epoll { epfd, .. } = &self.imp {
            sys::close(*epfd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn poller_reports_readable_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.register(server.as_raw_fd(), 7).unwrap();

        let mut ready = Vec::new();
        // nothing written yet: epoll times out empty; the scan
        // fallback over-approximates, which is also allowed
        poller.wait(Duration::from_millis(10), &mut ready).unwrap();

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let mut woke = false;
        for _ in 0..100 {
            poller.wait(Duration::from_millis(20), &mut ready).unwrap();
            if ready.contains(&7) {
                woke = true;
                break;
            }
        }
        assert!(woke, "readable socket never reported ready");

        poller.deregister(server.as_raw_fd(), 7).unwrap();
        poller.wait(Duration::from_millis(5), &mut ready).unwrap();
        assert!(!ready.contains(&7), "deregistered fd still reported");
    }
}
