"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package must match its oracle here to float32
tolerance; pytest + hypothesis sweep shapes/dtypes (python/tests/).
These definitions are also the normative arithmetic for the Rust
reimplementations in ``rust/src/compression`` and
``rust/src/coordinator/momentum.rs``.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_bias_act_ref(x, w, b=None, *, act: str = "none"):
    """Oracle for :func:`..matmul.matmul_bias_act`."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + b.astype(jnp.float32)
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "tanh":
        out = jnp.tanh(out)
    return out


def masked_scale_ref(g, mask, *, scale: float):
    """Oracle for :func:`..sparsify.masked_scale`."""
    return scale * g * mask


def momentum_update_ref(m_prev, g_tilde, *, beta: float):
    """Oracle for :func:`..sparsify.momentum_update`."""
    return beta * m_prev + (1.0 - beta) * g_tilde
