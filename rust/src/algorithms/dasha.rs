//! Byz-DASHA-PAGE — the SOTA comparator [29], in the gradient-descent
//! specialization the paper compares against (Appendix B: p = 1, full
//! gradients each round).
//!
//! Mechanics (per [29], with p = 1 the PAGE estimator is the exact local
//! gradient and the MVR term vanishes — what remains is DASHA's
//! compressed-*difference* scheme):
//!
//! * round 0: every worker uploads its **dense** gradient
//!   (`g_i^0 = ∇L_i(θ^0)`, the theorem's initialization);
//! * round t>0: worker i uploads `c_i^t = C_i(∇L_i(θ_t) − ĝ_i^{t−1})`
//!   with an *independent* RandK mask (unbiased compressor, as in [29]);
//!   server and worker both update the estimate
//!   `ĝ_i^t = ĝ_i^{t−1} + c_i^t`;
//! * server aggregates `R^t = F(ĝ_1^t, …, ĝ_n^t)`.
//!
//! As θ_t converges the differences shrink, so compression noise shrinks —
//! the variance-reduction effect that made Byz-DASHA-PAGE robust, at the
//! price of the bounded-Hessian-variance assumption in its analysis.
//!
//! Byzantine workers steer their server-side estimate toward the crafted
//! vector v by sending `C(v − ĝ_byz^{t−1})` (omniscient adversary: it
//! knows its own estimate).

use super::{byzantine_vectors, Algorithm, RoundEnv};
use crate::compression::codec::mask_wire_len;
use crate::compression::payload::{dasha_apply, Payload, TAG_DASHA};
use crate::compression::RandK;
use crate::transport::{
    compressed_grad_len, full_grad_len, payload_uplink_len,
};

pub struct ByzDashaPage {
    /// Server-side gradient estimates ĝ_i (identical to worker copies).
    estimates: Vec<Vec<f32>>,
    /// Scratch: difference vector.
    diff: Vec<f32>,
    /// Scratch: compressed difference (k floats, reused — §Perf: no
    /// per-worker payload allocation on the steady-state loop).
    payload: Vec<f32>,
    initialized: bool,
}

impl ByzDashaPage {
    pub fn new(d: usize, n_workers: usize) -> Self {
        ByzDashaPage {
            estimates: vec![vec![0.0; d]; n_workers],
            diff: vec![0.0; d],
            payload: Vec::new(),
            initialized: false,
        }
    }

    fn meter_dense(&self, env: &mut RoundEnv, worker: usize) {
        env.meter.record_uplink_sized(worker, full_grad_len(env.d));
    }

    fn meter_sparse(&self, env: &mut RoundEnv, worker: usize, k: usize) {
        // local mask: payload + mask wire (size-only, §Perf)
        env.meter.record_uplink_sized(
            worker,
            compressed_grad_len(k, mask_wire_len(env.d, k)),
        );
    }
}

impl Algorithm for ByzDashaPage {
    fn name(&self) -> &'static str {
        "byz-dasha-page"
    }

    fn round(
        &mut self,
        t: u64,
        honest_grads: &[Vec<f32>],
        byz_grads: &[Vec<f32>],
        env: &mut RoundEnv,
    ) -> Vec<f32> {
        let d = env.d;
        let n = env.n_total();
        debug_assert_eq!(self.estimates.len(), n);

        if let Some(ps) = env.payloads {
            // Wire payloads (tcp): each worker tracked its own estimate
            // copy remotely and shipped either the dense init gradient or
            // a masked difference; the server-side estimates advance
            // through the same `dasha_apply` law, staying in bit-exact
            // lockstep with the worker copies.
            for (widx, p) in ps.iter().enumerate() {
                env.meter
                    .record_uplink_sized(widx, payload_uplink_len(p));
                match p {
                    Payload::Dense { values } => {
                        debug_assert!(!self.initialized || env.k == d);
                        self.estimates[widx].copy_from_slice(values);
                    }
                    Payload::Sparse {
                        values,
                        mask: Some(mw),
                    } => {
                        dasha_apply(
                            &mut self.estimates[widx],
                            &mw.to_mask(),
                            values,
                        );
                    }
                    other => debug_assert!(
                        false,
                        "dasha expects dense or masked-difference \
                         payloads, got {other:?}"
                    ),
                }
            }
            self.initialized = true;
            let refs: Vec<&[f32]> =
                self.estimates.iter().map(|m| m.as_slice()).collect();
            return env.aggregator.aggregate_vec(&refs);
        }

        let byz = byzantine_vectors(t, honest_grads, byz_grads, env);
        let rk = RandK { d, k: env.k };

        // target vectors per worker: what each worker wants its estimate
        // to track this round.
        let update_worker =
            |this: &mut Self, widx: usize, target: &[f32], env: &mut RoundEnv| {
                if !this.initialized || env.k == d {
                    // dense init round (or no compression at all)
                    this.estimates[widx].copy_from_slice(target);
                    this.meter_dense(env, widx);
                    return;
                }
                // c = C_i(target - est); est += c (unbiased RandK)
                for (df, (tv, ev)) in this.diff.iter_mut().zip(
                    target.iter().zip(this.estimates[widx].iter()),
                ) {
                    *df = tv - ev;
                }
                let mut wrng = env.rng.derive(TAG_DASHA, t, widx as u64);
                let mask = rk.draw(&mut wrng);
                mask.compress_into(&this.diff, &mut this.payload);
                let payload_len = this.payload.len();
                this.meter_sparse(env, widx, payload_len);
                // est += a · α · scatter(payload) — DASHA's h-update law
                // with the stabilization stepsize a = 1/(2ω + 1); see
                // `payload::dasha_gain`. One shared function advances the
                // coordinator's estimates and every remote worker's local
                // copy, keeping them in bit-exact lockstep over the wire.
                dasha_apply(&mut this.estimates[widx], &mask, &this.payload);
            };

        for (i, g) in honest_grads.iter().enumerate() {
            update_worker(self, i, g, env);
        }
        for (j, v) in byz.iter().enumerate() {
            update_worker(self, env.n_honest + j, v, env);
        }
        self.initialized = true;

        let refs: Vec<&[f32]> =
            self.estimates.iter().map(|m| m.as_slice()).collect();
        env.aggregator.aggregate_vec(&refs)
    }

    fn momenta(&self) -> Option<&[Vec<f32>]> {
        Some(&self.estimates)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_env::Env;
    use super::*;
    use crate::tensor;

    #[test]
    fn first_round_is_dense_and_exact() {
        let mut env = Env::new(64, 4, 0, 8);
        let grads = env.constant_grads(3.0);
        let mut alg = ByzDashaPage::new(64, 4);
        let r = alg.round(0, &grads, &[], &mut env.env());
        for v in &r {
            assert!((v - 3.0).abs() < 1e-6);
        }
        // dense uplink: 4 workers * (12 + 4 + 64*4)
        assert_eq!(env.meter.uplink, 4 * (12 + 4 + 256));
    }

    #[test]
    fn estimates_track_changing_gradients() {
        // gradient drifts slowly; estimates must follow within noise.
        let d = 128;
        let mut env = Env::new(d, 3, 0, 32);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let mut alg = ByzDashaPage::new(d, 3);
        let mut g = vec![1.0f32; d];
        alg.round(0, &vec![g.clone(); 3], &[], &mut env.env());
        for t in 1..200u64 {
            for v in g.iter_mut() {
                *v *= 0.99;
            }
            alg.round(t, &vec![g.clone(); 3], &[], &mut env.env());
        }
        let est = &alg.estimates[0];
        let err = tensor::dist_sq(est, &g).sqrt() / tensor::norm(&g);
        assert!(err < 0.5, "relative tracking error {err}");
    }

    #[test]
    fn stationary_gradients_give_exact_estimates_in_expectation() {
        // constant g: diff -> 0 once estimate hits g; estimates converge.
        let d = 32;
        let mut env = Env::new(d, 2, 0, 8);
        env.aggregator = crate::aggregators::parse_spec("mean", 0).unwrap();
        let g: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).cos()).collect();
        let grads = vec![g.clone(); 2];
        let mut alg = ByzDashaPage::new(d, 2);
        for t in 0..100 {
            alg.round(t, &grads, &[], &mut env.env());
        }
        let err = tensor::dist_sq(&alg.estimates[0], &g);
        assert!(err < 1e-8, "residual {err}");
    }

    #[test]
    fn sparse_rounds_cost_less_than_dense() {
        let d = 11_809;
        let mut env = Env::new(d, 10, 0, 118);
        let grads = env.constant_grads(1.0);
        let mut alg = ByzDashaPage::new(d, 10);
        alg.round(0, &grads, &[], &mut env.env());
        let dense_cost = env.meter.uplink;
        alg.round(1, &grads, &[], &mut env.env());
        let sparse_cost = env.meter.uplink - dense_cost;
        assert!(
            (sparse_cost as f64) < 0.03 * dense_cost as f64,
            "sparse {sparse_cost} vs dense {dense_cost}"
        );
    }
}
