//! Splitting a dataset across honest workers.
//!
//! The paper randomly permutes MNIST and splits it equally among the 10
//! honest workers ("imperfect homogeneity"). [`partition_iid`] reproduces
//! that. [`partition_dirichlet`] adds the standard label-skew
//! non-iid partition used by the heterogeneity experiments
//! (`examples/global_vs_local.rs`), controlled by concentration `alpha`
//! (small alpha ⇒ strong skew ⇒ larger (G, B)).

use super::Dataset;
use crate::prng::Pcg64;

/// One worker's local data split.
pub type Shard = Dataset;

/// Random equal split (paper's setup).
pub fn partition_iid(ds: &Dataset, workers: usize, rng: &mut Pcg64) -> Vec<Shard> {
    assert!(workers > 0);
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut idx);
    let per = ds.len() / workers;
    assert!(per > 0, "fewer samples than workers");
    (0..workers)
        .map(|w| ds.subset(&idx[w * per..(w + 1) * per]))
        .collect()
}

/// Dirichlet(label-skew) split: for each class, worker shares are drawn
/// from Dir(alpha, ..., alpha). Every worker is guaranteed >= 1 sample.
pub fn partition_dirichlet(
    ds: &Dataset,
    workers: usize,
    alpha: f64,
    rng: &mut Pcg64,
) -> Vec<Shard> {
    assert!(workers > 0 && alpha > 0.0);
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); super::CLASSES];
    for i in 0..ds.len() {
        by_class[ds.labels[i] as usize].push(i);
    }
    let mut assign: Vec<Vec<usize>> = vec![Vec::new(); workers];
    for class_idx in by_class.iter_mut() {
        rng.shuffle(class_idx);
        // Dirichlet via normalized Gamma(alpha) draws.
        let mut w: Vec<f64> = (0..workers).map(|_| gamma(rng, alpha)).collect();
        let sum: f64 = w.iter().sum();
        for v in w.iter_mut() {
            *v /= sum;
        }
        let mut start = 0usize;
        for (widx, share) in w.iter().enumerate() {
            let take = if widx + 1 == workers {
                class_idx.len() - start
            } else {
                (share * class_idx.len() as f64).round() as usize
            };
            let take = take.min(class_idx.len() - start);
            assign[widx].extend_from_slice(&class_idx[start..start + take]);
            start += take;
        }
    }
    // guarantee non-empty shards (steal one sample from the largest)
    for w in 0..workers {
        if assign[w].is_empty() {
            let donor = (0..workers)
                .max_by_key(|&i| assign[i].len())
                .unwrap();
            let item = assign[donor].pop().unwrap();
            assign[w].push(item);
        }
    }
    assign.iter().map(|idx| ds.subset(idx)).collect()
}

/// Marsaglia–Tsang Gamma(k, 1) sampler (with Johnk-style boost for k < 1).
fn gamma(rng: &mut Pcg64, k: f64) -> f64 {
    if k < 1.0 {
        // Gamma(k) = Gamma(k+1) * U^{1/k}
        let u = rng.next_f64().max(f64::MIN_POSITIVE);
        return gamma(rng, k + 1.0) * u.powf(1.0 / k);
    }
    let d = k - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.next_gaussian();
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = rng.next_f64();
        if u < 1.0 - 0.0331 * x.powi(4)
            || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
        {
            return d * v3;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generate_synthetic;

    #[test]
    fn iid_split_is_partition() {
        let ds = generate_synthetic(1, 1000);
        let mut rng = Pcg64::new(2, 2);
        let shards = partition_iid(&ds, 10, &mut rng);
        assert_eq!(shards.len(), 10);
        assert!(shards.iter().all(|s| s.len() == 100));
    }

    #[test]
    fn iid_split_is_roughly_balanced_per_class() {
        let ds = generate_synthetic(1, 5000);
        let mut rng = Pcg64::new(3, 3);
        let shards = partition_iid(&ds, 10, &mut rng);
        for s in &shards {
            for &c in s.class_counts().iter() {
                // 50 expected; binomial sd ~ 6.7
                assert!((15..=90).contains(&c), "class count {c}");
            }
        }
    }

    #[test]
    fn dirichlet_small_alpha_skews() {
        let ds = generate_synthetic(1, 5000);
        let mut rng = Pcg64::new(4, 4);
        let shards = partition_dirichlet(&ds, 10, 0.1, &mut rng);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 5000);
        // with alpha=0.1 at least one worker should be strongly
        // class-concentrated: top class > 50% of its shard.
        let skewed = shards.iter().any(|s| {
            let counts = s.class_counts();
            let top = *counts.iter().max().unwrap();
            top * 2 > s.len()
        });
        assert!(skewed);
        assert!(shards.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn dirichlet_large_alpha_near_iid() {
        let ds = generate_synthetic(1, 5000);
        let mut rng = Pcg64::new(5, 5);
        let shards = partition_dirichlet(&ds, 5, 100.0, &mut rng);
        for s in &shards {
            let counts = s.class_counts();
            let (mn, mx) = (
                *counts.iter().min().unwrap(),
                *counts.iter().max().unwrap(),
            );
            assert!(mx < 3 * mn.max(1), "{counts:?}");
        }
    }

    #[test]
    fn gamma_mean_matches() {
        let mut rng = Pcg64::new(6, 6);
        for &k in &[0.3, 1.0, 4.0] {
            let n = 20_000;
            let m: f64 =
                (0..n).map(|_| gamma(&mut rng, k)).sum::<f64>() / n as f64;
            assert!((m - k).abs() < 0.1 * k.max(0.5), "k={k} mean={m}");
        }
    }
}
