//! End-to-end CLI tests: drive the compiled `rosdhb` binary the way a
//! user would (cargo exposes the path via `CARGO_BIN_EXE_rosdhb`).

use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_rosdhb"))
}

#[test]
fn info_runs() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("rosdhb"), "{text}");
}

#[test]
fn train_small_run_emits_json_report() {
    let out = bin()
        .args([
            "train",
            "--rounds", "5",
            "--train_size", "500",
            "--test_size", "100",
            "--n_honest", "4",
            "--n_byz", "1",
            "--batch", "20",
            "--stop_at_tau", "false",
            "--eval_every", "5",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8(out.stdout).unwrap();
    let j = rosdhb::util::json::Json::parse(text.trim()).unwrap();
    assert_eq!(j.get("rounds_run").unwrap().as_usize(), Some(5));
    assert!(j.get("uplink_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("config").unwrap().get("algorithm").is_some());
}

#[test]
fn train_rejects_bad_flags() {
    let out = bin()
        .args(["train", "--bogus_key", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bogus_key"), "{err}");

    let out = bin()
        .args(["train", "--n_byz", "10", "--n_honest", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "f >= n/2 must be rejected");
}

#[test]
fn train_with_config_file_and_override() {
    let dir = std::env::temp_dir();
    let cfg = dir.join("rosdhb_cli_test.toml");
    std::fs::write(
        &cfg,
        "[experiment]\nrounds = 4\ntrain_size = 400\ntest_size = 100\n\
         n_honest = 3\nn_byz = 1\nbatch = 20\nstop_at_tau = false\n",
    )
    .unwrap();
    let out = bin()
        .args([
            "train",
            "--config",
            cfg.to_str().unwrap(),
            "--rounds",
            "6", // override wins
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let j = rosdhb::util::json::Json::parse(
        String::from_utf8(out.stdout).unwrap().trim(),
    )
    .unwrap();
    assert_eq!(j.get("rounds_run").unwrap().as_usize(), Some(6));
}

#[test]
fn gb_command_reports_estimates() {
    let out = bin()
        .args([
            "gb",
            "--samples", "4",
            "--train_size", "500",
            "--test_size", "100",
            "--n_honest", "4",
            "--n_byz", "1",
            "--batch", "20",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8(out.stdout).unwrap();
    assert!(text.contains("G^2=") && text.contains("kappa"), "{text}");
}

#[test]
fn serve_and_join_run_as_separate_os_processes() {
    // n+1 real processes: 1 coordinator + 2 workers over loopback.
    // `serve` binds port 0; its stderr announces the actual address.
    let shared = [
        "--n_honest", "2",
        "--n_byz", "0",
        "--attack", "none",
        "--rounds", "2",
        "--train_size", "400",
        "--test_size", "100",
        "--batch", "20",
        "--eval_every", "2",
        "--stop_at_tau", "false",
        "--k_frac", "0.1",
        "--seed", "5",
    ];
    let mut serve = bin()
        .args(["serve", "--listen_addr", "127.0.0.1:0"])
        .args(shared)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    // scrape "listening on <addr>," off serve's stderr (and keep draining
    // the pipe so the child never blocks on it)
    let stderr = serve.stderr.take().unwrap();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel::<String>();
    let drain = std::thread::spawn(move || {
        let mut all = String::new();
        for line in BufReader::new(stderr).lines() {
            let line = line.unwrap_or_default();
            if let Some(rest) = line.split("listening on ").nth(1) {
                let addr = rest.split(',').next().unwrap_or("").trim();
                let _ = addr_tx.send(addr.to_string());
            }
            all.push_str(&line);
            all.push('\n');
        }
        all
    });
    let addr = addr_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("serve must announce its address");

    let joins: Vec<_> = (0..2)
        .map(|_| {
            bin()
                .args(["join", "--coordinator_addr", &addr])
                .args(shared)
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .unwrap()
        })
        .collect();
    for j in joins {
        let out = j.wait_with_output().unwrap();
        assert!(
            out.status.success(),
            "join failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let status = serve.wait().unwrap();
    let serve_err = drain.join().unwrap();
    assert!(status.success(), "serve failed: {serve_err}");
    assert!(
        serve_err.contains("measured wire bytes"),
        "missing byte report: {serve_err}"
    );
}

/// Spawn `rosdhb serve --listen_addr 127.0.0.1:0 <extra> <shared>`,
/// scrape the bound address off its stderr, and keep draining the pipe
/// (returned handle yields the full stderr text).
fn spawn_serve(
    extra: &[&str],
    shared: &[&str],
) -> (std::process::Child, String, std::thread::JoinHandle<String>) {
    let mut serve = bin()
        .args(["serve", "--listen_addr", "127.0.0.1:0"])
        .args(extra)
        .args(shared)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let stderr = serve.stderr.take().unwrap();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel::<String>();
    let drain = std::thread::spawn(move || {
        let mut all = String::new();
        for line in BufReader::new(stderr).lines() {
            let line = line.unwrap_or_default();
            if let Some(rest) = line.split("listening on ").nth(1) {
                let addr = rest.split(',').next().unwrap_or("").trim();
                let _ = addr_tx.send(addr.to_string());
            }
            all.push_str(&line);
            all.push('\n');
        }
        all
    });
    let addr = addr_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("serve must announce its address");
    (serve, addr, drain)
}

#[test]
fn serve_sigkilled_mid_run_restores_bit_identically() {
    // Crash-recovery across real OS processes: a coordinator is
    // SIGKILLed after an epoch-boundary checkpoint hits disk; a fresh
    // coordinator process restoring from that file (with fresh worker
    // processes) must print the exact same final report as a coordinator
    // that was never killed. Whichever boundary the kill lands after,
    // every checkpoint lies on the same trajectory, so the comparison is
    // immune to kill timing.
    let shared = [
        "--n_honest", "2",
        "--n_byz", "0",
        "--attack", "none",
        "--rounds", "12",
        "--epoch_rounds", "2",
        "--train_size", "400",
        "--test_size", "100",
        "--batch", "20",
        "--eval_every", "2",
        "--stop_at_tau", "false",
        "--k_frac", "0.1",
        "--seed", "11",
    ];
    let spawn_joins = |addr: &str| -> Vec<std::process::Child> {
        (0..2)
            .map(|_| {
                bin()
                    .args(["join", "--coordinator_addr", addr])
                    .args(shared)
                    .stdout(Stdio::piped())
                    .stderr(Stdio::piped())
                    .spawn()
                    .unwrap()
            })
            .collect()
    };

    // reference: the same config, never killed
    let (mut serve, addr, drain) = spawn_serve(&[], &shared);
    let joins = spawn_joins(&addr);
    for j in joins {
        let out = j.wait_with_output().unwrap();
        assert!(
            out.status.success(),
            "reference join failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let straight = serve.wait_with_output().unwrap();
    let serve_err = drain.join().unwrap();
    assert!(straight.status.success(), "reference serve failed: {serve_err}");

    // the victim: checkpoints armed, killed as soon as one hits disk
    let ckpt = std::env::temp_dir().join(format!(
        "rosdhb_cli_sigkill_{}.ckpt",
        std::process::id()
    ));
    std::fs::remove_file(&ckpt).ok();
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    let (mut victim, addr, victim_drain) =
        spawn_serve(&["--checkpoint", &ckpt_s], &shared);
    let victim_joins = spawn_joins(&addr);
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(120);
    while !ckpt.exists() {
        assert!(
            std::time::Instant::now() < deadline,
            "no checkpoint appeared before the deadline"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    victim.kill().ok(); // SIGKILL — no flush, no cleanup
    victim.wait().unwrap();
    victim_drain.join().unwrap();
    for j in victim_joins {
        // they die on the broken socket (or finished, if the run outran
        // the kill) — either way just reap them
        let _ = j.wait_with_output().unwrap();
    }

    // restore into a brand-new coordinator with fresh worker processes
    let (mut restored, addr, restored_drain) =
        spawn_serve(&["--restore", &ckpt_s], &shared);
    let joins = spawn_joins(&addr);
    for j in joins {
        let out = j.wait_with_output().unwrap();
        assert!(
            out.status.success(),
            "restored join failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let out = restored.wait_with_output().unwrap();
    let err = restored_drain.join().unwrap();
    assert!(out.status.success(), "restored serve failed: {err}");
    std::fs::remove_file(&ckpt).ok();

    assert_eq!(
        String::from_utf8_lossy(&straight.stdout),
        String::from_utf8_lossy(&out.stdout),
        "restored run must print a bit-identical report"
    );
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}
