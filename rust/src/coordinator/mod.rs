//! The L3 coordinator — the paper's system contribution as a runnable
//! server.
//!
//! [`Trainer`] owns the model parameters, the worker pool, the algorithm
//! state machine, the byte-metered transport and the metrics log, and
//! drives the synchronous round loop of Algorithm 1:
//!
//! ```text
//! per round t:
//!   broadcast θ_{t-1} (+ global mask seed)        — algorithm meters it
//!   workers: g_i = ∇L_i(θ_{t-1}) on a fresh batch — engine (PJRT/native)
//!   Byzantine payload injection                    — attacks
//!   server: reconstruct → momentum → F(m_1..m_n)   — algorithm
//!   θ_t = θ_{t-1} − γ R^t
//!   every eval_every rounds: test accuracy, τ-crossing, Lyapunov diag
//! ```

use crate::algorithms::{self, Algorithm, RoundEnv};
use crate::attacks::{self, AttackKind};
use crate::aggregators::{self, Aggregator};
use crate::compression::RandK;
use crate::config::{Dataset as DatasetCfg, Engine, ExperimentConfig};
use crate::data::{self, Dataset};
use crate::diagnostics;
use crate::metrics::{MetricsLog, RoundRecord};
use crate::model::MlpSpec;
use crate::prng::Pcg64;
use crate::tensor;
use crate::transport::ByteMeter;
use crate::worker::{GradEngine, HonestWorker, NativeEngine, PjrtEngine};
use anyhow::{anyhow, Result};

/// End-of-run summary (plus the full per-round log).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algorithm: String,
    pub rounds_run: usize,
    /// First round at which test accuracy ≥ τ (None if never reached).
    pub rounds_to_tau: Option<usize>,
    /// Cumulative uplink bytes at the τ-crossing (the Fig. 1 y-axis).
    pub uplink_bytes_to_tau: Option<u64>,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub best_acc: Option<f64>,
    pub final_loss: Option<f64>,
    pub log: MetricsLog,
}

/// The coordinator.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    engine: Box<dyn GradEngine>,
    honest: Vec<HonestWorker>,
    /// Data-level Byzantine workers (label-flip); empty for payload
    /// attacks.
    byz_data_workers: Vec<HonestWorker>,
    algorithm: Box<dyn Algorithm>,
    aggregator: Box<dyn Aggregator>,
    attack: AttackKind,
    pub params: Vec<f32>,
    test_set: Dataset,
    meter: ByteMeter,
    rng: Pcg64,
    pub log: MetricsLog,
    k: usize,
    /// Set when loss/update became non-finite; `run()` stops gracefully.
    pub diverged: bool,
    /// Per-worker engines for the parallel native gradient path (§Perf);
    /// empty under PJRT (the client is not Send) — sequential there.
    par_engines: Vec<NativeEngine>,
}

impl Trainer {
    /// Build everything from a validated config.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let root = Pcg64::new(cfg.seed, 0);

        // --- engine
        let mut engine: Box<dyn GradEngine> = match cfg.engine {
            Engine::Native => {
                Box::new(NativeEngine::new(MlpSpec::default(), cfg.batch.max(1)))
            }
            Engine::Pjrt => Box::new(PjrtEngine::load(&cfg.artifacts_dir)?),
        };
        let d = engine.p();

        // --- data
        let (train, test) = match &cfg.dataset {
            DatasetCfg::Synthetic => data::generate_synthetic_split(
                cfg.seed ^ 0xdada,
                cfg.train_size,
                cfg.test_size,
            ),
            DatasetCfg::MnistIdx(dir) => data::load_mnist_idx(dir)
                .map_err(|e| anyhow!("mnist: {e}"))?,
        };
        let mut part_rng = root.derive(0x7061_7274, 0, 0);
        let shards = match crate::config::parse_partition(&cfg.partition)
            .map_err(|e| anyhow!(e))?
        {
            None => data::partition_iid(&train, cfg.n_honest, &mut part_rng),
            Some(alpha) => data::partition_dirichlet(
                &train,
                cfg.n_honest,
                alpha,
                &mut part_rng,
            ),
        };
        let honest: Vec<HonestWorker> = shards
            .into_iter()
            .enumerate()
            .map(|(i, s)| HonestWorker::new(i, s, &root, false))
            .collect();

        // --- attack & (for label-flip) poisoned byzantine workers
        let attack = attacks::parse_spec(&cfg.attack).map_err(|e| anyhow!(e))?;
        let byz_data_workers = if matches!(attack, AttackKind::LabelFlip) {
            (0..cfg.n_byz)
                .map(|j| {
                    // each poisoned worker clones an honest shard
                    let shard = honest[j % cfg.n_honest].shard.clone();
                    HonestWorker::new(cfg.n_honest + j, shard, &root, true)
                })
                .collect()
        } else {
            Vec::new()
        };

        let aggregator = aggregators::parse_spec(&cfg.aggregator, cfg.n_byz)
            .map_err(|e| anyhow!(e))?;
        let algorithm = algorithms::build(cfg, d);
        let params = engine.init_params(cfg.seed ^ 0x1a17)?;
        let k = RandK::from_frac(d, cfg.k_frac).k;

        // parallel gradient engines (native only; bit-identical to the
        // sequential path since each worker's RNG stream is independent)
        let n_grad_workers = honest.len() + byz_data_workers.len();
        let par_engines = if cfg.engine == Engine::Native && n_grad_workers > 1
        {
            (0..n_grad_workers)
                .map(|_| NativeEngine::new(MlpSpec::default(), cfg.batch.max(1)))
                .collect()
        } else {
            Vec::new()
        };

        Ok(Trainer {
            cfg: cfg.clone(),
            engine,
            honest,
            byz_data_workers,
            algorithm,
            aggregator,
            attack,
            params,
            test_set: test,
            meter: ByteMeter::new(cfg.n_total()),
            rng: root.derive(0x726f_756e, 1, 0),
            log: MetricsLog::default(),
            k,
            diverged: false,
            par_engines,
        })
    }

    /// Robustness coefficient bound of the configured aggregator at (n,f).
    pub fn kappa_bound(&self) -> f64 {
        self.aggregator
            .kappa(self.cfg.n_total(), self.cfg.n_byz)
    }

    /// One synchronous round; returns (mean honest loss, ‖R‖).
    pub fn step(&mut self, t: u64) -> Result<(f64, f64)> {
        // workers compute gradients (PJRT sequential; native in parallel —
        // identical numerics, each worker has its own RNG stream/engine)
        let nh = self.honest.len();
        let (mut honest_grads, mut byz_grads, mean_loss);
        if self.par_engines.is_empty() {
            honest_grads = Vec::with_capacity(nh);
            let mut loss_sum = 0.0f64;
            for w in self.honest.iter_mut() {
                let (loss, g) = w.compute_grad(
                    self.engine.as_mut(),
                    &self.params,
                    self.cfg.batch,
                )?;
                loss_sum += loss as f64;
                honest_grads.push(g);
            }
            mean_loss = loss_sum / nh as f64;
            byz_grads = Vec::with_capacity(self.byz_data_workers.len());
            for w in self.byz_data_workers.iter_mut() {
                let (_, g) = w.compute_grad(
                    self.engine.as_mut(),
                    &self.params,
                    self.cfg.batch,
                )?;
                byz_grads.push(g);
            }
        } else {
            let params = &self.params;
            let batch = self.cfg.batch;
            let (h_eng, b_eng) = self.par_engines.split_at_mut(nh);
            let honest = &mut self.honest;
            let byz = &mut self.byz_data_workers;
            let (h_res, b_res) = std::thread::scope(|s| {
                let hs: Vec<_> = honest
                    .iter_mut()
                    .zip(h_eng.iter_mut())
                    .map(|(w, e)| {
                        s.spawn(move || w.compute_grad(e, params, batch))
                    })
                    .collect();
                let bs: Vec<_> = byz
                    .iter_mut()
                    .zip(b_eng.iter_mut())
                    .map(|(w, e)| {
                        s.spawn(move || w.compute_grad(e, params, batch))
                    })
                    .collect();
                let h: Vec<_> =
                    hs.into_iter().map(|h| h.join().unwrap()).collect();
                let b: Vec<_> =
                    bs.into_iter().map(|h| h.join().unwrap()).collect();
                (h, b)
            });
            let mut loss_sum = 0.0f64;
            honest_grads = Vec::with_capacity(nh);
            for r in h_res {
                let (loss, g) = r?;
                loss_sum += loss as f64;
                honest_grads.push(g);
            }
            mean_loss = loss_sum / nh as f64;
            byz_grads = Vec::with_capacity(b_eng.len());
            for r in b_res {
                byz_grads.push(r?.1);
            }
        }

        let mut env = RoundEnv {
            d: self.params.len(),
            n_honest: self.cfg.n_honest,
            n_byz: self.cfg.n_byz,
            seed: self.cfg.seed,
            k: self.k,
            beta: self.cfg.beta,
            aggregator: self.aggregator.as_ref(),
            attack: &self.attack,
            meter: &mut self.meter,
            rng: &mut self.rng,
        };
        let mut update = self
            .algorithm
            .round(t, &honest_grads, &byz_grads, &mut env);
        // optional update clipping (production stabilizer; off by default)
        if self.cfg.clip > 0.0 {
            let n = tensor::norm(&update);
            if n.is_finite() && n > self.cfg.clip as f64 {
                tensor::scale(&mut update, self.cfg.clip / n as f32);
            }
        }

        // Lyapunov diagnostics (against the sampled honest mean gradient).
        let lyapunov = if self.cfg.lyapunov {
            self.algorithm.momenta().map(|m| {
                let refs: Vec<&[f32]> = m[..self.cfg.n_honest]
                    .iter()
                    .map(|v| v.as_slice())
                    .collect();
                let grefs: Vec<&[f32]> =
                    honest_grads.iter().map(|g| g.as_slice()).collect();
                let gh = tensor::mean(&grefs);
                let snap = diagnostics::snapshot(&refs, &gh);
                (snap.deviation_sq, snap.drift)
            })
        } else {
            None
        };

        // θ_t = θ_{t-1} − γ_t R^t  (γ_t = γ·decay^t; decay=1 ⇒ constant)
        let gamma_t = if self.cfg.gamma_decay >= 1.0 {
            self.cfg.gamma
        } else {
            self.cfg.gamma * self.cfg.gamma_decay.powi(t as i32)
        };
        tensor::axpy(&mut self.params, -gamma_t, &update);
        let update_norm = tensor::norm(&update);
        if !update_norm.is_finite() || !mean_loss.is_finite() {
            self.diverged = true;
        }

        // bookkeeping row (test_acc filled by run())
        self.log.push(RoundRecord {
            round: t as usize,
            train_loss: mean_loss,
            update_norm,
            test_acc: None,
            uplink_bytes: self.meter.uplink,
            downlink_bytes: self.meter.downlink,
            lyapunov,
        });
        Ok((mean_loss, update_norm))
    }

    /// Current test accuracy.
    pub fn evaluate(&mut self) -> Result<f64> {
        self.engine.accuracy(&self.params, &self.test_set)
    }

    /// Fresh honest batch gradients at the current model (diagnostics /
    /// (G,B) estimation; does not advance training state).
    pub fn probe_honest_gradients(&mut self) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(self.honest.len());
        for w in self.honest.iter_mut() {
            let (_, g) =
                w.compute_grad(self.engine.as_mut(), &self.params, self.cfg.batch)?;
            out.push(g);
        }
        Ok(out)
    }

    /// Run the full loop per the config; returns the report.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut reached: Option<(usize, u64)> = None;
        for t in 1..=self.cfg.rounds as u64 {
            self.step(t)?;
            if self.diverged {
                eprintln!(
                    "rosdhb: run diverged at round {t} (non-finite loss/update) — stopping"
                );
                break;
            }
            if t as usize % self.cfg.eval_every == 0
                || t as usize == self.cfg.rounds
            {
                let acc = self.evaluate()?;
                if let Some(row) = self.log.rows.last_mut() {
                    row.test_acc = Some(acc);
                }
                if acc >= self.cfg.tau && reached.is_none() {
                    reached = Some((t as usize, self.meter.uplink));
                    if self.cfg.stop_at_tau {
                        break;
                    }
                }
            }
        }
        if let Some(path) = &self.cfg.csv_out {
            self.log.save_csv(path)?;
        }
        Ok(RunReport {
            algorithm: self.algorithm.name().to_string(),
            rounds_run: self.log.rows.len(),
            rounds_to_tau: reached.map(|(r, _)| r),
            uplink_bytes_to_tau: reached.map(|(_, b)| b),
            uplink_bytes: self.meter.uplink,
            downlink_bytes: self.meter.downlink,
            best_acc: self.log.best_acc(),
            final_loss: self.log.final_loss(),
            log: self.log.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default_mnist_like();
        c.train_size = 600;
        c.test_size = 200;
        c.rounds = 30;
        c.eval_every = 10;
        c.n_honest = 4;
        c.n_byz = 1;
        c.batch = 30;
        c.gamma = 0.2;
        c.k_frac = 0.1;
        c.stop_at_tau = false;
        c.aggregator = "cwtm".into();
        c
    }

    #[test]
    fn trainer_builds_and_steps() {
        let mut t = Trainer::from_config(&tiny_cfg()).unwrap();
        let (l1, _) = t.step(1).unwrap();
        let (l2, _) = t.step(2).unwrap();
        assert!(l1.is_finite() && l2.is_finite());
        assert!(t.meter.uplink > 0 && t.meter.downlink > 0);
    }

    #[test]
    fn training_reduces_loss_under_attack() {
        let mut cfg = tiny_cfg();
        cfg.rounds = 120;
        cfg.attack = "alie".into();
        cfg.aggregator = "nnm+cwtm".into();
        let mut t = Trainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        let first = report.log.rows.first().unwrap().train_loss;
        let last = report.final_loss.unwrap();
        assert!(
            last < 0.8 * first,
            "loss should fall: {first} -> {last}"
        );
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let cfg = tiny_cfg();
        let r1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let r2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(r1.final_loss, r2.final_loss);
        assert_eq!(r1.uplink_bytes, r2.uplink_bytes);
    }

    #[test]
    fn labelflip_builds_poisoned_workers() {
        let mut cfg = tiny_cfg();
        cfg.attack = "labelflip".into();
        cfg.n_byz = 2;
        let mut t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.byz_data_workers.len(), 2);
        assert!(t.byz_data_workers.iter().all(|w| w.poisoned));
        t.step(1).unwrap();
    }

    #[test]
    fn lyapunov_rows_populated_when_enabled() {
        let mut cfg = tiny_cfg();
        cfg.lyapunov = true;
        cfg.rounds = 3;
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.step(1).unwrap();
        assert!(t.log.rows[0].lyapunov.is_some());
        let (dev, drift) = t.log.rows[0].lyapunov.unwrap();
        assert!(dev.is_finite() && drift.is_finite());
    }

    #[test]
    fn kappa_bound_reflects_aggregator() {
        let mut cfg = tiny_cfg();
        cfg.aggregator = "mean".into();
        let t = Trainer::from_config(&cfg).unwrap();
        assert!(t.kappa_bound().is_infinite());
        cfg.aggregator = "nnm+cwtm".into();
        let t = Trainer::from_config(&cfg).unwrap();
        assert!(t.kappa_bound().is_finite());
    }

    #[test]
    fn parallel_and_sequential_grads_agree() {
        // forcing the sequential path (drop par_engines) must reproduce
        // the parallel path bit-for-bit — same RNG streams per worker.
        let cfg = tiny_cfg();
        let mut par = Trainer::from_config(&cfg).unwrap();
        let mut seq = Trainer::from_config(&cfg).unwrap();
        seq.par_engines.clear();
        for t in 1..=5 {
            let (lp, up) = par.step(t).unwrap();
            let (ls, us) = seq.step(t).unwrap();
            assert_eq!(lp, ls, "round {t} loss");
            assert_eq!(up, us, "round {t} update norm");
        }
        assert_eq!(par.params, seq.params);
    }

    #[test]
    fn clip_caps_update_norm() {
        let mut cfg = tiny_cfg();
        cfg.clip = 1e-3;
        cfg.rounds = 3;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let p0 = t.params.clone();
        t.step(1).unwrap();
        let moved: f64 = p0
            .iter()
            .zip(&t.params)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            moved <= (cfg.clip * cfg.gamma) as f64 * 1.001,
            "moved {moved}"
        );
    }

    #[test]
    fn gamma_decay_shrinks_steps() {
        let mut cfg = tiny_cfg();
        cfg.gamma_decay = 0.5;
        cfg.attack = "none".into();
        cfg.n_byz = 0;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let p0 = t.params.clone();
        t.step(1).unwrap();
        let d1: f64 = crate::tensor::dist_sq(&p0, &t.params).sqrt();
        for r in 2..=8 {
            t.step(r).unwrap();
        }
        let p8 = t.params.clone();
        t.step(9).unwrap();
        let d9: f64 = crate::tensor::dist_sq(&p8, &t.params).sqrt();
        // after 8 halvings the step is ~256x smaller (modulo momentum)
        assert!(d9 < d1 * 0.1, "d1={d1} d9={d9}");
    }

    #[test]
    fn bytes_scale_with_k_frac() {
        let mut a = tiny_cfg();
        a.k_frac = 0.01;
        a.rounds = 5;
        let mut b = a.clone();
        b.k_frac = 1.0;
        let ra = Trainer::from_config(&a).unwrap().run().unwrap();
        let rb = Trainer::from_config(&b).unwrap().run().unwrap();
        assert!(
            ra.uplink_bytes * 20 < rb.uplink_bytes,
            "k/d=0.01 uplink {} vs dense {}",
            ra.uplink_bytes,
            rb.uplink_bytes
        );
    }
}
