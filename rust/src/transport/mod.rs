//! In-process transport with exact byte accounting.
//!
//! The paper's headline experiment (Fig. 1) measures *communication cost to
//! reach τ accuracy*, so the wire format is a first-class object here, not
//! an afterthought: every server↔worker message has a concrete encoding
//! ([`WireMessage::encode`]), and the [`ByteMeter`] sums exactly
//! `encode().len()` per message (tests pin `encoded_len == encode().len()`).
//!
//! Accounting model:
//! * **Downlink** (server → workers, broadcast): model `d·4` bytes + the
//!   message header + an 8-byte mask seed under global sparsification (the
//!   whole mask is never shipped — both ends re-derive it from the seed).
//!   Under `downlink = "delta"` the broadcast is an [`WireMessage::UpdateBroadcast`]
//!   instead — the previous aggregate, delta-coded to the k masked values
//!   on carry rounds (see [`downlink`]); [`ByteMeter`] additionally splits
//!   delivered bytes from coordinator egress for relay-tree fan-out.
//! * **Uplink** (worker → server): one [`WireMessage::Grad`] per worker —
//!   a message header plus the body of a typed
//!   [`Payload`][crate::compression::payload::Payload]. The payload codec
//!   ([`crate::compression::payload`]) is the single byte-layout
//!   authority: sparse bodies are `[u32 count][k·f32][mask?]` (mask via
//!   the cheaper of the index-list / bitset codecs in
//!   `compression::codec`), dense bodies are `[u32 count][d·f32]`, and
//!   quantized bodies are packed QSGD blocks
//!   ([`QuantBlock`][crate::compression::payload::QuantBlock]).
//!
//! The format is no longer simulation-only: [`WireMessage::decode`] is the
//! exact inverse of [`WireMessage::encode`], and [`net`] runs the same
//! bytes over blocking TCP (length-prefixed frames) for the
//! `transport = "tcp"` coordinator/worker runtime. The same frames can
//! instead be driven by a readiness-based event loop ([`evloop`],
//! `io = "evloop"`): one thread per process, nonblocking sockets, and a
//! connection [`monitor`] whose latency/gap estimates steer relay-tree
//! placement and stalled-relay resyncs — delivery-path decisions only,
//! never payload bytes, so the threaded runtime remains the bit-parity
//! oracle.

pub mod downlink;
pub mod evloop;
pub mod monitor;
pub mod net;
pub mod poller;
pub mod uplink;

use crate::compression::payload::{Payload, QuantBlock};

/// Message header: 8-byte round id + 2-byte type tag + 2-byte worker id.
pub const HEADER_BYTES: usize = 12;

/// First wire tag of the uplink family; tag = `GRAD_TAG_BASE +
/// payload.kind()`, so sparse (2) and dense (3) uplinks keep the byte
/// layout of the pre-payload wire format and quantized uplinks extend it
/// at tag 4. Grad tags occupy `[2, 257]`.
const GRAD_TAG_BASE: u16 = 2;

/// Wire tag of [`WireMessage::UpdateBroadcast`] — the first tag above the
/// grad family's `[GRAD_TAG_BASE, GRAD_TAG_BASE + 255]` range.
const UPDATE_TAG: u16 = 258;

/// Wire tag of [`WireMessage::Leave`].
const LEAVE_TAG: u16 = 259;

/// All messages that cross the (simulated or real) network.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMessage {
    /// Server → all workers under **global** sparsification: model + the
    /// seed from which workers re-derive mask(k).
    ModelBroadcast {
        round: u64,
        params: Vec<f32>,
        mask_seed: u64,
    },
    /// Server → all workers when workers choose their own masks (local
    /// sparsification / quantization / no compression).
    ModelBroadcastPlain { round: u64, params: Vec<f32> },
    /// Server → all workers under `downlink = "delta"`: the *previous*
    /// round's aggregate update `R^{round-1}` instead of the model —
    /// workers keep a replica and step it locally
    /// ([`downlink::DownlinkReplica`]). The payload is sparse (the k
    /// masked values; off-mask the carry law `β·R_prev` applies, with the
    /// mask re-derived from `prev_mask_seed`) on carry rounds and dense
    /// (the full update) on fallback rounds; an *empty* dense payload is
    /// the round-1 sync frame (no update yet).
    UpdateBroadcast {
        round: u64,
        /// Seed of the mask the sparse payload's values live on (round
        /// `round − 1`'s shared mask); 0 for dense/sync frames.
        prev_mask_seed: u64,
        /// The carry coefficient β of the off-mask reconstruction.
        beta: f32,
        payload: Payload,
    },
    /// Worker → server: one typed compressed-gradient payload. The wire
    /// tag encodes the payload kind; the body is exactly the payload
    /// body, so the codec in [`crate::compression::payload`] is the
    /// single byte-layout authority for every uplink.
    Grad {
        round: u64,
        worker: u16,
        payload: Payload,
    },
    /// Worker → server: graceful departure notice. Sent immediately
    /// before the worker's *final* uplink of the epoch; the coordinator
    /// vacates the slot at the next epoch boundary (never mid-epoch, so
    /// the round arithmetic stays deterministic). `round` is the last
    /// round the worker will serve.
    Leave { round: u64, worker: u16 },
}

impl WireMessage {
    /// Exact serialized size in bytes (hot path — no allocation).
    pub fn encoded_len(&self) -> usize {
        match self {
            WireMessage::ModelBroadcast { params, .. } => {
                HEADER_BYTES + 8 + 4 * params.len()
            }
            WireMessage::ModelBroadcastPlain { params, .. } => {
                HEADER_BYTES + 4 * params.len()
            }
            WireMessage::UpdateBroadcast { payload, .. } => {
                HEADER_BYTES + 8 + 4 + payload.encoded_len()
            }
            WireMessage::Grad { payload, .. } => {
                HEADER_BYTES + payload.body_len()
            }
            WireMessage::Leave { .. } => HEADER_BYTES,
        }
    }

    /// Full serialization (little-endian) — the bytes the TCP runtime
    /// moves; the simulator meters via [`Self::encoded_len`].
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        let (tag, round, worker): (u16, u64, u16) = match self {
            WireMessage::ModelBroadcast { round, .. } => (0, *round, 0),
            WireMessage::ModelBroadcastPlain { round, .. } => (1, *round, 0),
            WireMessage::UpdateBroadcast { round, .. } => {
                (UPDATE_TAG, *round, 0)
            }
            WireMessage::Grad {
                round,
                worker,
                payload,
            } => (GRAD_TAG_BASE + payload.kind() as u16, *round, *worker),
            WireMessage::Leave { round, worker } => {
                (LEAVE_TAG, *round, *worker)
            }
        };
        out.extend_from_slice(&round.to_le_bytes());
        out.extend_from_slice(&tag.to_le_bytes());
        out.extend_from_slice(&worker.to_le_bytes());
        match self {
            WireMessage::ModelBroadcast {
                params, mask_seed, ..
            } => {
                out.extend_from_slice(&mask_seed.to_le_bytes());
                for v in params {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            WireMessage::ModelBroadcastPlain { params, .. } => {
                for v in params {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            WireMessage::UpdateBroadcast {
                prev_mask_seed,
                beta,
                payload,
                ..
            } => {
                out.extend_from_slice(&prev_mask_seed.to_le_bytes());
                out.extend_from_slice(&beta.to_le_bytes());
                payload.encode_into(&mut out);
            }
            WireMessage::Grad { payload, .. } => {
                payload.encode_body_into(&mut out);
            }
            WireMessage::Leave { .. } => {}
        }
        debug_assert_eq!(out.len(), self.encoded_len());
        out
    }

    /// Exact inverse of [`Self::encode`] over one complete message.
    ///
    /// `d` is the model dimension, needed only to rebuild uplink payloads
    /// (masks and quantized blocks do not carry `d` on the wire — both
    /// ends know it). Malformed or truncated input returns `Err`, never
    /// panics; trailing bytes are rejected so a length-prefixed frame
    /// must contain exactly one message.
    pub fn decode(buf: &[u8], d: usize) -> Result<WireMessage, String> {
        if buf.len() < HEADER_BYTES {
            return Err(format!(
                "frame too short: {} bytes < {HEADER_BYTES}-byte header",
                buf.len()
            ));
        }
        let round = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let tag = u16::from_le_bytes([buf[8], buf[9]]);
        let worker = u16::from_le_bytes([buf[10], buf[11]]);
        let body = &buf[HEADER_BYTES..];
        match tag {
            0 => {
                if body.len() < 8 {
                    return Err("ModelBroadcast: missing mask seed".into());
                }
                let mask_seed = u64::from_le_bytes(body[0..8].try_into().unwrap());
                let params = decode_f32s(&body[8..], "ModelBroadcast params")?;
                Ok(WireMessage::ModelBroadcast {
                    round,
                    params,
                    mask_seed,
                })
            }
            1 => Ok(WireMessage::ModelBroadcastPlain {
                round,
                params: decode_f32s(body, "ModelBroadcastPlain params")?,
            }),
            UPDATE_TAG => {
                if body.len() < 13 {
                    return Err(
                        "UpdateBroadcast: body too short for seed + beta + payload"
                            .into(),
                    );
                }
                let prev_mask_seed =
                    u64::from_le_bytes(body[0..8].try_into().unwrap());
                let beta =
                    f32::from_le_bytes(body[8..12].try_into().unwrap());
                let payload = Payload::decode(&body[12..], d)?;
                Ok(WireMessage::UpdateBroadcast {
                    round,
                    prev_mask_seed,
                    beta,
                    payload,
                })
            }
            LEAVE_TAG => {
                if !body.is_empty() {
                    return Err("Leave: unexpected body bytes".into());
                }
                Ok(WireMessage::Leave { round, worker })
            }
            t if t >= GRAD_TAG_BASE && t - GRAD_TAG_BASE <= u8::MAX as u16 => {
                let kind = (t - GRAD_TAG_BASE) as u8;
                let payload = Payload::decode_body(kind, body, d)?;
                Ok(WireMessage::Grad {
                    round,
                    worker,
                    payload,
                })
            }
            t => Err(format!("unknown wire tag {t}")),
        }
    }

    pub fn is_uplink(&self) -> bool {
        matches!(self, WireMessage::Grad { .. })
    }
}

/// Parse the rest of a buffer as packed little-endian f32s.
fn decode_f32s(buf: &[u8], what: &str) -> Result<Vec<f32>, String> {
    if buf.len() % 4 != 0 {
        return Err(format!("{what}: {} bytes is not a whole number of f32s", buf.len()));
    }
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Cumulative byte counters for one experiment.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ByteMeter {
    /// Total worker→server bytes (summed over all n workers — the server
    /// cannot distinguish Byzantine uplinks, so they count too, as in the
    /// paper).
    pub uplink: u64,
    /// Total server→worker bytes **delivered** (broadcast counted once
    /// per recipient, however the copy reached the worker — coordinator
    /// write or relay forward).
    pub downlink: u64,
    /// The subset of [`Self::downlink`] the coordinator itself put on the
    /// wire. Equal to `downlink` under flat fan-out; under a relay tree
    /// (`fanout = "tree"`) only `branching` copies per round are
    /// coordinator egress, the rest is worker-to-worker forwarding.
    pub coordinator_egress: u64,
    /// The subset of [`Self::uplink`] the coordinator itself received.
    /// Equal to `uplink` under value-forwarding (every uplink travels
    /// end-to-end); under `uplink = "aggregate"` on a relay tree only the
    /// root subtrees' accumulated frames are coordinator ingress, the
    /// rest is worker-to-worker folding traffic
    /// ([`uplink::meter_model`]).
    pub coordinator_ingress: u64,
    /// Uplink bytes per worker id.
    pub per_worker_uplink: Vec<u64>,
}

impl ByteMeter {
    pub fn new(n_workers: usize) -> Self {
        ByteMeter {
            uplink: 0,
            downlink: 0,
            coordinator_egress: 0,
            coordinator_ingress: 0,
            per_worker_uplink: vec![0; n_workers],
        }
    }

    /// Record a broadcast delivered to `n_recipients` workers.
    pub fn record_broadcast(&mut self, msg: &WireMessage, n_recipients: usize) {
        debug_assert!(!msg.is_uplink());
        self.record_broadcast_sized(msg.encoded_len(), n_recipients);
    }

    /// Fan-out-aware broadcast record: `delivered` total recipients, of
    /// which `egress_copies` were written by the coordinator itself
    /// ([`downlink::FanoutPlan::direct_count`]); the remainder traveled
    /// worker-to-worker through the relay tree.
    pub fn record_broadcast_fanout(
        &mut self,
        bytes: usize,
        delivered: usize,
        egress_copies: usize,
    ) {
        self.downlink += bytes as u64 * delivered as u64;
        self.coordinator_egress += bytes as u64 * egress_copies as u64;
    }

    /// Record one worker→server message.
    pub fn record_uplink(&mut self, msg: &WireMessage) {
        debug_assert!(msg.is_uplink());
        let worker = match msg {
            WireMessage::Grad { worker, .. } => *worker as usize,
            _ => unreachable!(),
        };
        let len = msg.encoded_len() as u64;
        self.uplink += len;
        self.coordinator_ingress += len;
        if worker < self.per_worker_uplink.len() {
            self.per_worker_uplink[worker] += len;
        }
    }

    /// Hot-path variant: record an uplink by its precomputed wire size
    /// (see [`payload_uplink_len`] / [`compressed_grad_len`] /
    /// [`full_grad_len`] / [`quant_grad_len`]) without building a
    /// message. Tests pin these helpers against `encode().len()`.
    pub fn record_uplink_sized(&mut self, worker: usize, bytes: usize) {
        self.uplink += bytes as u64;
        self.coordinator_ingress += bytes as u64;
        if worker < self.per_worker_uplink.len() {
            self.per_worker_uplink[worker] += bytes as u64;
        }
    }

    /// Record an uplink frame that terminated at another *worker* (a
    /// relay folding its subtree under `uplink = "aggregate"`): counted
    /// as delivered uplink and attributed to the sender, but not as
    /// coordinator ingress.
    pub fn record_relayed_uplink(&mut self, worker: usize, bytes: usize) {
        self.uplink += bytes as u64;
        if worker < self.per_worker_uplink.len() {
            self.per_worker_uplink[worker] += bytes as u64;
        }
    }

    /// Hot-path variant of [`Self::record_broadcast`] (flat fan-out:
    /// every delivered copy is coordinator egress).
    pub fn record_broadcast_sized(&mut self, bytes: usize, n_recipients: usize) {
        self.record_broadcast_fanout(bytes, n_recipients, n_recipients);
    }

    pub fn total(&self) -> u64 {
        self.uplink + self.downlink
    }
}

/// Wire size of any [`WireMessage::Grad`] carrying `p` — the one-line
/// bridge between the typed payload and the uplink byte model.
pub fn payload_uplink_len(p: &Payload) -> usize {
    HEADER_BYTES + p.body_len()
}

/// Wire size of a sparse uplink with `k` payload floats and an optional
/// mask of `mask_bytes` (from `MaskWire::encoded_len` or
/// [`crate::compression::codec::mask_wire_len`]).
pub fn compressed_grad_len(k: usize, mask_bytes: usize) -> usize {
    HEADER_BYTES + 4 + 4 * k + mask_bytes
}

/// Wire size of a dense uplink of `d` floats.
pub fn full_grad_len(d: usize) -> usize {
    HEADER_BYTES + 4 + 4 * d
}

/// Wire size of a QSGD-quantized uplink at dimension `d`, `s` levels.
pub fn quant_grad_len(d: usize, s: u32) -> usize {
    HEADER_BYTES + QuantBlock::body_len(d, s)
}

/// Wire size of a `ModelBroadcast{Plain}` of `d` parameters.
pub fn broadcast_len(d: usize, with_mask_seed: bool) -> usize {
    HEADER_BYTES + if with_mask_seed { 8 } else { 0 } + 4 * d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::codec::MaskWire;
    use crate::compression::payload::QuantBlock;

    fn sample_grads(d: usize) -> Vec<WireMessage> {
        let mask = MaskWire::index_list(&[1, 5, 9], d);
        vec![
            WireMessage::Grad {
                round: 3,
                worker: 7,
                payload: Payload::Sparse {
                    values: vec![0.5; 10],
                    mask: None,
                },
            },
            WireMessage::Grad {
                round: 3,
                worker: 7,
                payload: Payload::Sparse {
                    values: vec![0.5; 3],
                    mask: Some(mask),
                },
            },
            WireMessage::Grad {
                round: 1,
                worker: 0,
                payload: Payload::Dense {
                    values: vec![0.0; 64],
                },
            },
            WireMessage::Grad {
                round: 9,
                worker: 2,
                payload: Payload::Quantized(QuantBlock {
                    s: 4,
                    norm: 1.5,
                    levels: vec![0, -3, 4, 1, 0, 0, -1],
                }),
            },
        ]
    }

    /// One UpdateBroadcast per payload shape the delta downlink emits:
    /// sync (empty dense), delta (mask-less sparse), dense fallback.
    fn sample_updates(d: usize) -> Vec<WireMessage> {
        vec![
            WireMessage::UpdateBroadcast {
                round: 1,
                prev_mask_seed: 0,
                beta: 0.9,
                payload: Payload::Dense { values: Vec::new() },
            },
            WireMessage::UpdateBroadcast {
                round: 5,
                prev_mask_seed: 0xfeed,
                beta: 0.9,
                payload: Payload::Sparse {
                    values: vec![1.5; 7],
                    mask: None,
                },
            },
            WireMessage::UpdateBroadcast {
                round: 6,
                prev_mask_seed: 0,
                beta: 0.5,
                payload: Payload::Dense {
                    values: vec![-0.25; d],
                },
            },
        ]
    }

    #[test]
    fn encoded_len_matches_encode() {
        let mut msgs = vec![
            WireMessage::ModelBroadcast {
                round: 3,
                params: vec![1.0; 100],
                mask_seed: 42,
            },
            WireMessage::ModelBroadcastPlain {
                round: 3,
                params: vec![1.0; 100],
            },
        ];
        msgs.extend(sample_grads(100));
        msgs.extend(sample_updates(100));
        msgs.push(WireMessage::Leave { round: 12, worker: 3 });
        for m in msgs {
            assert_eq!(m.encode().len(), m.encoded_len(), "{m:?}");
        }
    }

    #[test]
    fn decode_is_exact_inverse_of_encode() {
        // d must match each payload: sparse/dense use d=100/64 freely
        // (masks carry their own indices), the quant block has d=7.
        let msgs = vec![
            (
                100usize,
                WireMessage::ModelBroadcast {
                    round: 9,
                    params: vec![0.25; 17],
                    mask_seed: 0xdead_beef,
                },
            ),
            (
                100,
                WireMessage::ModelBroadcastPlain {
                    round: 1,
                    params: vec![-1.5; 3],
                },
            ),
            (100, sample_grads(100)[0].clone()),
            (100, sample_grads(100)[1].clone()),
            (64, sample_grads(100)[2].clone()),
            (7, sample_grads(100)[3].clone()),
            (100, sample_updates(100)[0].clone()),
            (100, sample_updates(100)[1].clone()),
            (100, sample_updates(100)[2].clone()),
            (100, WireMessage::Leave { round: 8, worker: 2 }),
        ];
        for (d, m) in msgs {
            let bytes = m.encode();
            assert_eq!(WireMessage::decode(&bytes, d).unwrap(), m, "{m:?}");
            // any 1-byte truncation must be a clean error, not a panic
            assert!(
                WireMessage::decode(&bytes[..bytes.len() - 1], d).is_err(),
                "{m:?}"
            );
        }
        assert!(WireMessage::decode(&[], 10).is_err());
    }

    #[test]
    fn grad_tags_track_payload_kinds() {
        // the wire tag is 2 + payload kind, preserving the pre-payload
        // byte layout for sparse (2) and dense (3) uplinks.
        for (msg, want_tag) in sample_grads(100).iter().zip([2u8, 2, 3, 4]) {
            let bytes = msg.encode();
            assert_eq!(bytes[8], want_tag, "{msg:?}");
            assert_eq!(bytes[9], 0);
        }
    }

    #[test]
    fn meter_accumulates_directionally() {
        let mut meter = ByteMeter::new(3);
        let bcast = WireMessage::ModelBroadcast {
            round: 0,
            params: vec![0.0; 10],
            mask_seed: 1,
        };
        meter.record_broadcast(&bcast, 3);
        assert_eq!(meter.downlink, 3 * bcast.encoded_len() as u64);
        // flat fan-out: every delivered copy is coordinator egress
        assert_eq!(meter.coordinator_egress, meter.downlink);
        assert_eq!(meter.uplink, 0);

        // tree fan-out: 3 delivered, only 1 written by the coordinator
        meter.record_broadcast_fanout(100, 3, 1);
        assert_eq!(
            meter.downlink,
            3 * bcast.encoded_len() as u64 + 300
        );
        assert_eq!(
            meter.coordinator_egress,
            3 * bcast.encoded_len() as u64 + 100
        );

        let up = WireMessage::Grad {
            round: 0,
            worker: 2,
            payload: Payload::Sparse {
                values: vec![1.0; 4],
                mask: None,
            },
        };
        meter.record_uplink(&up);
        assert_eq!(meter.uplink, up.encoded_len() as u64);
        assert_eq!(meter.per_worker_uplink, vec![0, 0, up.encoded_len() as u64]);
        assert_eq!(meter.total(), meter.uplink + meter.downlink);
    }

    #[test]
    fn sized_helpers_match_real_messages() {
        for msg in sample_grads(100) {
            let WireMessage::Grad { payload, .. } = &msg else {
                unreachable!()
            };
            assert_eq!(
                payload_uplink_len(payload),
                msg.encoded_len(),
                "{msg:?}"
            );
        }
        assert_eq!(
            compressed_grad_len(10, 0),
            sample_grads(100)[0].encoded_len()
        );
        assert_eq!(full_grad_len(64), sample_grads(100)[2].encoded_len());
        assert_eq!(quant_grad_len(7, 4), sample_grads(100)[3].encoded_len());
    }

    #[test]
    fn compression_saves_bytes_on_the_wire() {
        // the point of the whole paper, at the message level:
        let dense = WireMessage::Grad {
            round: 0,
            worker: 0,
            payload: Payload::Dense {
                values: vec![0.0; 11_809],
            },
        };
        let sparse = WireMessage::Grad {
            round: 0,
            worker: 0,
            payload: Payload::Sparse {
                values: vec![0.0; 118], // k/d = 0.01
                mask: None,             // global mask: seed travels downlink
            },
        };
        let ratio = sparse.encoded_len() as f64 / dense.encoded_len() as f64;
        assert!(ratio < 0.011, "ratio={ratio}");
    }
}
