//! The live coordinator status endpoint (`serve --status_addr <addr>`).
//!
//! A [`StatusServer`] binds one read-only TCP listener and routes a
//! handful of observer paths:
//!
//! * `GET /` — one JSON snapshot of the run: epoch and round, per-slot
//!   membership with the RTT/jitter estimates of
//!   [`transport::monitor`][crate::transport::monitor], cumulative
//!   byte meters (both the modeled
//!   [`ByteMeter`][crate::transport::ByteMeter] view and the measured
//!   [`NetStats`][crate::transport::net::NetStats]), resync/eviction
//!   counts, geometry rebuild counters, per-worker suspicion scores
//!   ([`telemetry::forensics`][crate::telemetry::forensics]), and the
//!   worker-pushed side-channel stats.
//! * `GET /history` — the bounded in-memory ring of the last *H*
//!   per-round snapshot rows (`config: status_history`).
//! * `GET /events` — an SSE stream of journal events as they are
//!   recorded (via [`Telemetry::set_event_tap`][crate::telemetry::Telemetry::set_event_tap]),
//!   each line as one `data:` frame.
//! * `GET /clock` — the coordinator's journal-clock reading, the anchor
//!   workers probe to align their own journal timestamps.
//! * `POST /worker` — the **side channel**: workers push their phase
//!   histograms, gap-monitor view and clock offset here, *never* over
//!   the data sockets — the tracing-invariance oracle on raw
//!   data-socket bytes must keep holding with every telemetry feature
//!   live.
//!
//! Anything else gets a real `404`. Every non-streaming response is
//! written through one choke point that computes `Content-Length` from
//! the body it writes, so `curl <addr>` works, as does a bare `nc`
//! (an unparsable request still receives the snapshot).
//!
//! The endpoint is **observer-only**: nothing arriving here can mutate
//! the run — worker pushes land in a display-only map — and the
//! listener lives on its own thread driven by
//! [`transport::poller`][crate::transport::poller]; the trainer only
//! ever *pushes* a fresh [`StatusState`] into the shared cell at the
//! end of each round, so the round loop never blocks on a slow (or
//! malicious) status client. SSE clients get a dedicated thread each,
//! keeping the accept loop responsive.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::transport::monitor::SlotHealth;
use crate::transport::net::NetStats;
use crate::transport::poller::Poller;
use crate::util::json::Json;

use std::collections::{BTreeMap, VecDeque};

/// Default depth of the `/history` ring (`config: status_history`).
pub const DEFAULT_HISTORY_DEPTH: usize = 64;

/// The snapshot served to each connection. The trainer overwrites it
/// once per round; serving renders whatever was last pushed.
#[derive(Clone, Debug, Default)]
pub struct StatusState {
    pub algorithm: String,
    /// Rounds the run will attempt (`config: rounds`).
    pub rounds_total: u64,
    /// Last completed round (0 until the first round finishes).
    pub round: u64,
    pub epoch: u64,
    /// Per-slot membership + monitor estimates (empty for the local
    /// transport, which has no sockets).
    pub slots: Vec<SlotHealth>,
    /// Measured socket counters (`None` for the local transport).
    pub net: Option<NetStats>,
    /// Modeled byte-meter view — comparable across transports.
    pub uplink_bytes: u64,
    /// The subset of `uplink_bytes` the coordinator itself received —
    /// smaller than `uplink_bytes` only under `uplink = "aggregate"`
    /// on a relay tree, where interior relays fold their subtrees.
    pub coordinator_ingress_bytes: u64,
    /// Ingress-minus-uplink mirror of `relayed_downlink_bytes`: bytes
    /// worker relays folded into accumulated frames (0 under
    /// value-forwarding).
    pub relayed_uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub coordinator_egress_bytes: u64,
    /// Delivered-minus-egress: bytes the relay tree moved for the
    /// coordinator (0 under flat fan-out).
    pub relayed_downlink_bytes: u64,
    /// RESYNC frames the coordinator absorbed.
    pub relay_resyncs: u64,
    /// Workers dropped from later rounds.
    pub evictions: u64,
    /// Latest `(‖δᵗ‖², Υᵗ)` when `config: lyapunov` is on.
    pub lyapunov: Option<(f64, f64)>,
    /// Events journaled so far (0 when tracing is off).
    pub trace_events: u64,
    /// Pairwise-geometry maintenance counters `(rebuilds,
    /// incrementals)` — `None` for rules that keep no geometry.
    pub geometry: Option<(u64, u64)>,
    /// Rolling per-worker suspicion scores (`config: forensics`;
    /// empty when off).
    pub suspicion: Vec<f64>,
    /// Worker-pushed side-channel stats (`POST /worker`), keyed by
    /// worker id — phase histograms, gap-monitor view, clock offset.
    /// Display-only: nothing in the run reads this back.
    pub workers: BTreeMap<u64, Json>,
}

impl StatusState {
    fn render(&self) -> String {
        let num = |v: u64| Json::Num(v as f64);
        let mut o = BTreeMap::new();
        o.insert("algorithm".into(), Json::Str(self.algorithm.clone()));
        o.insert("rounds_total".into(), num(self.rounds_total));
        o.insert("round".into(), num(self.round));
        o.insert("epoch".into(), num(self.epoch));
        o.insert(
            "live_slots".into(),
            num(self.slots.iter().filter(|s| s.active).count() as u64),
        );
        let slots: Vec<Json> = self
            .slots
            .iter()
            .map(|s| {
                let mut so = BTreeMap::new();
                so.insert("slot".into(), num(s.slot as u64));
                so.insert("active".into(), Json::Bool(s.active));
                so.insert(
                    "rtt_ms".into(),
                    s.rtt_ms.map_or(Json::Null, Json::Num),
                );
                so.insert(
                    "jitter_ms".into(),
                    s.jitter_ms.map_or(Json::Null, Json::Num),
                );
                so.insert("samples".into(), num(s.samples));
                Json::Obj(so)
            })
            .collect();
        o.insert("slots".into(), Json::Arr(slots));
        o.insert(
            "net".into(),
            match self.net {
                None => Json::Null,
                Some(n) => {
                    let mut no = BTreeMap::new();
                    no.insert("wire_uplink".into(), num(n.wire_uplink));
                    no.insert("wire_downlink".into(), num(n.wire_downlink));
                    no.insert("raw_uplink".into(), num(n.raw_uplink));
                    no.insert("raw_downlink".into(), num(n.raw_downlink));
                    Json::Obj(no)
                }
            },
        );
        o.insert("uplink_bytes".into(), num(self.uplink_bytes));
        o.insert(
            "coordinator_ingress_bytes".into(),
            num(self.coordinator_ingress_bytes),
        );
        o.insert(
            "relayed_uplink_bytes".into(),
            num(self.relayed_uplink_bytes),
        );
        o.insert("downlink_bytes".into(), num(self.downlink_bytes));
        o.insert(
            "coordinator_egress_bytes".into(),
            num(self.coordinator_egress_bytes),
        );
        o.insert(
            "relayed_downlink_bytes".into(),
            num(self.relayed_downlink_bytes),
        );
        o.insert("relay_resyncs".into(), num(self.relay_resyncs));
        o.insert("evictions".into(), num(self.evictions));
        o.insert(
            "lyapunov".into(),
            match self.lyapunov {
                None => Json::Null,
                Some((dev, drift)) => {
                    let mut lo = BTreeMap::new();
                    lo.insert("deviation_sq".into(), Json::Num(dev));
                    lo.insert("drift".into(), Json::Num(drift));
                    Json::Obj(lo)
                }
            },
        );
        o.insert("trace_events".into(), num(self.trace_events));
        o.insert(
            "geometry".into(),
            match self.geometry {
                None => Json::Null,
                Some((rebuilds, incrementals)) => {
                    let mut go = BTreeMap::new();
                    go.insert("rebuilds".into(), num(rebuilds));
                    go.insert("incrementals".into(), num(incrementals));
                    Json::Obj(go)
                }
            },
        );
        o.insert(
            "suspicion".into(),
            Json::Arr(
                self.suspicion
                    .iter()
                    .map(|&v| Json::Num((v * 1e4).round() / 1e4))
                    .collect(),
            ),
        );
        o.insert(
            "workers".into(),
            Json::Obj(
                self.workers
                    .iter()
                    .map(|(id, v)| (id.to_string(), v.clone()))
                    .collect(),
            ),
        );
        Json::Obj(o).to_string()
    }
}

/// Bounded ring of rendered per-round snapshot rows behind `/history`.
struct HistoryRing {
    rows: VecDeque<String>,
    depth: usize,
}

/// The coordinator-clock reading served by `/clock`.
type ClockSource = Arc<dyn Fn() -> u64 + Send + Sync>;

/// Shared cell between the trainer (writer) and the listener thread
/// (reader). Cloning shares the same state.
#[derive(Clone)]
pub struct StatusHandle {
    state: Arc<Mutex<StatusState>>,
    history: Arc<Mutex<HistoryRing>>,
    subs: Arc<Mutex<Vec<mpsc::Sender<String>>>>,
    clock: Arc<Mutex<Option<ClockSource>>>,
    /// Fallback `/clock` origin when no source is installed (untraced
    /// coordinator with the endpoint on).
    t0: Arc<Instant>,
}

impl StatusHandle {
    fn new() -> Self {
        StatusHandle {
            state: Arc::new(Mutex::new(StatusState::default())),
            history: Arc::new(Mutex::new(HistoryRing {
                rows: VecDeque::new(),
                depth: DEFAULT_HISTORY_DEPTH,
            })),
            subs: Arc::new(Mutex::new(Vec::new())),
            clock: Arc::new(Mutex::new(None)),
            t0: Arc::new(Instant::now()),
        }
    }

    /// Overwrite fields under the lock (the trainer's per-round push).
    pub fn update<F: FnOnce(&mut StatusState)>(&self, f: F) {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut s);
    }

    /// Render the current snapshot (what a connection receives).
    pub fn render(&self) -> String {
        self.state
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .render()
    }

    /// Resize the `/history` ring (`config: status_history`); 0 keeps
    /// nothing.
    pub fn set_history_depth(&self, depth: usize) {
        let mut h = lock(&self.history);
        h.depth = depth;
        while h.rows.len() > depth {
            h.rows.pop_front();
        }
    }

    /// Append the *current* snapshot to the history ring — the
    /// trainer's end-of-round call, right after `update`.
    pub fn push_history(&self) {
        let row = self.render();
        let mut h = lock(&self.history);
        if h.depth == 0 {
            return;
        }
        if h.rows.len() == h.depth {
            h.rows.pop_front();
        }
        h.rows.push_back(row);
    }

    /// Render the `/history` reply: ring depth + the retained rows,
    /// oldest first.
    pub fn render_history(&self) -> String {
        let h = lock(&self.history);
        let mut out = String::from("{\"depth\":");
        out.push_str(&h.depth.to_string());
        out.push_str(",\"rows\":[");
        for (i, row) in h.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(row);
        }
        out.push_str("]}");
        out
    }

    /// Rows currently retained (tests).
    pub fn history_len(&self) -> usize {
        lock(&self.history).rows.len()
    }

    /// Fan one rendered journal line out to every live `/events`
    /// subscriber, pruning the dead. This is the coordinator
    /// telemetry's event tap.
    pub fn publish_event(&self, line: &str) {
        let mut subs = lock(&self.subs);
        subs.retain(|tx| tx.send(line.to_string()).is_ok());
    }

    /// Subscribe to the journal-event stream (one SSE connection).
    fn subscribe(&self) -> mpsc::Receiver<String> {
        let (tx, rx) = mpsc::channel();
        lock(&self.subs).push(tx);
        rx
    }

    /// Install the `/clock` reading — the coordinator's journal clock
    /// when tracing is on, so worker offsets align the *journals*.
    pub fn set_clock_source(&self, src: ClockSource) {
        *lock(&self.clock) = Some(src);
    }

    /// The `/clock` reading served to probes.
    pub fn clock_now_us(&self) -> u64 {
        match lock(&self.clock).clone() {
            Some(src) => src(),
            None => self.t0.elapsed().as_micros() as u64,
        }
    }

    /// Record one worker's side-channel push (`POST /worker`).
    pub fn worker_update(&self, id: u64, stats: Json) {
        self.update(|s| {
            s.workers.insert(id, stats);
        });
    }
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The bound endpoint: listener thread + shared state. Dropping it
/// stops the thread and closes the listener.
pub struct StatusServer {
    addr: SocketAddr,
    handle: StatusHandle,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatusServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7900"`; port 0 picks one) and
    /// start serving snapshots.
    pub fn bind(addr: &str) -> std::io::Result<StatusServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let handle = StatusHandle::new();
        let stop = Arc::new(AtomicBool::new(false));
        let mut poller = Poller::new()?;
        poller.register(listener.as_raw_fd(), 0)?;
        let thread = {
            let handle = handle.clone();
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("rosdhb-status".into())
                .spawn(move || {
                    let mut ready = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let _ = poller
                            .wait(Duration::from_millis(200), &mut ready);
                        if ready.is_empty() {
                            continue;
                        }
                        loop {
                            match listener.accept() {
                                Ok((stream, _)) => {
                                    serve_one(stream, &handle, &stop)
                                }
                                Err(e)
                                    if e.kind()
                                        == std::io::ErrorKind::WouldBlock =>
                                {
                                    break
                                }
                                Err(_) => break,
                            }
                        }
                    }
                })?
        };
        Ok(StatusServer {
            addr: local,
            handle,
            stop,
            thread: Some(thread),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn handle(&self) -> StatusHandle {
        self.handle.clone()
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The single choke point every non-streaming response goes through —
/// the `Content-Length` audit: the header is computed from the exact
/// body bytes written on the line below, so no path can desynchronize
/// them.
fn write_http(stream: &mut TcpStream, status: &str, body: &str) {
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

/// Parse `"METHOD /path HTTP/x"` out of a raw request. `None` when the
/// bytes don't look like HTTP at all (bare `nc`) — those connections
/// keep receiving the snapshot.
fn parse_request_line(seen: &[u8]) -> Option<(String, String)> {
    let head = std::str::from_utf8(seen).ok()?;
    let line = head.split("\r\n").next()?;
    let mut parts = line.split_ascii_whitespace();
    let method = parts.next()?;
    let path = parts.next()?;
    let version = parts.next()?;
    if !version.starts_with("HTTP/") || !path.starts_with('/') {
        return None;
    }
    // strip any query string — routing is by path only
    let path = path.split('?').next().unwrap_or(path);
    Some((method.to_ascii_uppercase(), path.to_string()))
}

/// `Content-Length` of a request whose header block ends at
/// `header_end` (0 when absent or unparsable).
fn content_length(seen: &[u8], header_end: usize) -> usize {
    let head = match std::str::from_utf8(&seen[..header_end]) {
        Ok(h) => h,
        Err(_) => return 0,
    };
    head.split("\r\n")
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse().ok())
        .unwrap_or(0)
}

/// Answer one connection: read the request (headers plus, for `POST`,
/// the declared body — readiness only ever hints, so short timeouts
/// bound every read), route by path, and reply through [`write_http`].
/// `/events` hands the socket to a dedicated streaming thread so the
/// accept loop stays responsive.
fn serve_one(
    mut stream: TcpStream,
    handle: &StatusHandle,
    stop: &Arc<AtomicBool>,
) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut buf = [0u8; 1024];
    let mut seen: Vec<u8> = Vec::new();
    let mut header_end: Option<usize> = None;
    let mut want_body = 0usize;
    loop {
        if let Some(he) = header_end {
            if seen.len() >= he + want_body {
                break;
            }
        }
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                seen.extend_from_slice(&buf[..n]);
                if header_end.is_none() {
                    if let Some(pos) =
                        seen.windows(4).position(|w| w == b"\r\n\r\n")
                    {
                        let he = pos + 4;
                        header_end = Some(he);
                        // bound a hostile Content-Length: pushes are
                        // small JSON objects
                        want_body = content_length(&seen, he).min(65536);
                    }
                }
                if seen.len() > 128 * 1024 {
                    break;
                }
            }
            Err(_) => break, // timeout or reset — route what arrived
        }
    }
    let request = parse_request_line(&seen);
    match request.as_ref().map(|(m, p)| (m.as_str(), p.as_str())) {
        // bare `nc` (no parsable request line) keeps getting the
        // snapshot; parsed-but-unknown paths get a real 404 below
        None | Some(("GET", "/")) => {
            write_http(&mut stream, "200 OK", &handle.render());
        }
        Some(("GET", "/history")) => {
            write_http(&mut stream, "200 OK", &handle.render_history());
        }
        Some(("GET", "/clock")) => {
            let body = format!("{{\"ts_us\":{}}}", handle.clock_now_us());
            write_http(&mut stream, "200 OK", &body);
        }
        Some(("GET", "/events")) => {
            let rx = handle.subscribe();
            let stop = Arc::clone(stop);
            // detached: exits on client disconnect or server stop
            let _ = std::thread::Builder::new()
                .name("rosdhb-status-sse".into())
                .spawn(move || stream_events(stream, rx, stop));
        }
        Some(("POST", "/worker")) => {
            let he = header_end.unwrap_or(seen.len());
            let body = seen
                .get(he..)
                .and_then(|b| std::str::from_utf8(b).ok())
                .unwrap_or("");
            match Json::parse(body.trim()) {
                Ok(j) => {
                    let id = j
                        .get("worker")
                        .and_then(Json::as_f64)
                        .map(|v| v as u64);
                    match id {
                        Some(id) => {
                            handle.worker_update(id, j);
                            write_http(
                                &mut stream,
                                "200 OK",
                                "{\"ok\":true}",
                            );
                        }
                        None => write_http(
                            &mut stream,
                            "400 Bad Request",
                            "{\"error\":\"missing worker id\"}",
                        ),
                    }
                }
                Err(_) => write_http(
                    &mut stream,
                    "400 Bad Request",
                    "{\"error\":\"bad json\"}",
                ),
            }
        }
        Some(_) => {
            write_http(
                &mut stream,
                "404 Not Found",
                "{\"error\":\"not found\"}",
            );
        }
    }
}

/// Drive one `/events` SSE client: forward every published journal
/// line as a `data:` frame until the client hangs up or the server
/// stops.
fn stream_events(
    mut stream: TcpStream,
    rx: mpsc::Receiver<String>,
    stop: Arc<AtomicBool>,
) {
    let header = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
                  Cache-Control: no-cache\r\nConnection: close\r\n\r\n";
    if stream.write_all(header.as_bytes()).is_err() {
        return;
    }
    let _ = stream.flush();
    while !stop.load(Ordering::Relaxed) {
        match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(line) => {
                let frame = format!("data: {line}\n\n");
                if stream.write_all(frame.as_bytes()).is_err() {
                    return;
                }
                let _ = stream.flush();
            }
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Raw HTTP request against the endpoint, returning `(head, body)`
    /// after auditing that `Content-Length` matches the body bytes.
    fn http_raw(addr: SocketAddr, request: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        let (head, body) = out
            .split_once("\r\n\r\n")
            .expect("response must carry a header/body split");
        let cl: usize = head
            .split("\r\n")
            .filter_map(|l| l.split_once(':'))
            .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
            .map(|(_, v)| v.trim().parse().unwrap())
            .expect("every non-streaming response declares a length");
        assert_eq!(cl, body.len(), "Content-Length audit failed");
        (head.to_string(), body.to_string())
    }

    /// Raw HTTP GET of `/`, returning the body.
    fn http_get(addr: SocketAddr) -> String {
        let (head, body) =
            http_raw(addr, "GET / HTTP/1.0\r\nHost: x\r\n\r\n");
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        body
    }

    #[test]
    fn status_server_serves_one_snapshot_per_connection() {
        let srv = StatusServer::bind("127.0.0.1:0").unwrap();
        srv.handle().update(|s| {
            s.algorithm = "rosdhb".into();
            s.round = 3;
            s.epoch = 1;
            s.rounds_total = 8;
            s.slots = vec![
                SlotHealth {
                    slot: 0,
                    active: true,
                    rtt_ms: Some(1.25),
                    jitter_ms: Some(0.5),
                    samples: 3,
                },
                SlotHealth {
                    slot: 1,
                    active: false,
                    rtt_ms: None,
                    jitter_ms: None,
                    samples: 0,
                },
            ];
            s.uplink_bytes = 100;
            s.lyapunov = Some((2.0, 0.25));
        });
        let body = http_get(srv.local_addr());
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("round").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("epoch").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("live_slots").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("uplink_bytes").and_then(Json::as_f64), Some(100.0));
        let lyap = j.get("lyapunov").unwrap();
        assert_eq!(
            lyap.get("deviation_sq").and_then(Json::as_f64),
            Some(2.0)
        );
        // a second connection sees the *updated* state
        srv.handle().update(|s| s.round = 4);
        let j2 = Json::parse(&http_get(srv.local_addr())).unwrap();
        assert_eq!(j2.get("round").and_then(Json::as_f64), Some(4.0));
    }

    #[test]
    fn unknown_paths_get_a_real_404_and_known_routes_answer() {
        let srv = StatusServer::bind("127.0.0.1:0").unwrap();
        let addr = srv.local_addr();
        let (head, body) =
            http_raw(addr, "GET /nope HTTP/1.0\r\nHost: x\r\n\r\n");
        assert!(head.starts_with("HTTP/1.1 404"), "head: {head}");
        assert!(body.contains("not found"));
        let (head, _) =
            http_raw(addr, "DELETE / HTTP/1.0\r\nHost: x\r\n\r\n");
        assert!(head.starts_with("HTTP/1.1 404"), "head: {head}");
        // /clock serves a monotone microsecond reading
        let (head, body) =
            http_raw(addr, "GET /clock HTTP/1.0\r\nHost: x\r\n\r\n");
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        let t1 = Json::parse(&body)
            .unwrap()
            .get("ts_us")
            .and_then(Json::as_f64)
            .unwrap();
        let (_, body) =
            http_raw(addr, "GET /clock HTTP/1.0\r\nHost: x\r\n\r\n");
        let t2 = Json::parse(&body)
            .unwrap()
            .get("ts_us")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(t2 >= t1, "clock went backwards: {t1} → {t2}");
        // an installed source overrides the fallback origin
        srv.handle().set_clock_source(Arc::new(|| 42));
        let (_, body) =
            http_raw(addr, "GET /clock HTTP/1.0\r\nHost: x\r\n\r\n");
        assert_eq!(body, "{\"ts_us\":42}");
    }

    #[test]
    fn history_ring_is_bounded_and_served_oldest_first() {
        let srv = StatusServer::bind("127.0.0.1:0").unwrap();
        let h = srv.handle();
        h.set_history_depth(3);
        for r in 1..=5u64 {
            h.update(|s| s.round = r);
            h.push_history();
        }
        assert_eq!(h.history_len(), 3);
        let (head, body) = http_raw(
            srv.local_addr(),
            "GET /history HTTP/1.0\r\nHost: x\r\n\r\n",
        );
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("depth").and_then(Json::as_f64), Some(3.0));
        let rounds: Vec<f64> = match j.get("rows").unwrap() {
            Json::Arr(rows) => rows
                .iter()
                .map(|r| r.get("round").and_then(Json::as_f64).unwrap())
                .collect(),
            other => panic!("rows must be an array, got {other:?}"),
        };
        assert_eq!(rounds, vec![3.0, 4.0, 5.0]);
        // shrinking the depth trims the oldest rows
        h.set_history_depth(1);
        assert_eq!(h.history_len(), 1);
    }

    #[test]
    fn worker_post_lands_in_the_snapshot_and_bad_posts_are_400() {
        let srv = StatusServer::bind("127.0.0.1:0").unwrap();
        let addr = srv.local_addr();
        let payload = "{\"worker\":2,\"round\":7,\"offset_us\":-1500}";
        let req = format!(
            "POST /worker HTTP/1.0\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            payload.len(),
            payload
        );
        let (head, body) = http_raw(addr, &req);
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        assert_eq!(body, "{\"ok\":true}");
        let snap = Json::parse(&http_get(addr)).unwrap();
        let w2 = snap.get("workers").unwrap().get("2").unwrap();
        assert_eq!(w2.get("round").and_then(Json::as_f64), Some(7.0));
        assert_eq!(
            w2.get("offset_us").and_then(Json::as_f64),
            Some(-1500.0)
        );
        // a push without a worker id is rejected, not silently dropped
        let req = "POST /worker HTTP/1.0\r\nHost: x\r\n\
                   Content-Length: 2\r\n\r\n{}";
        let (head, _) = http_raw(addr, req);
        assert!(head.starts_with("HTTP/1.1 400"), "head: {head}");
    }

    #[test]
    fn events_stream_forwards_published_lines_as_sse_frames() {
        let srv = StatusServer::bind("127.0.0.1:0").unwrap();
        let h = srv.handle();
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        s.write_all(b"GET /events HTTP/1.0\r\nHost: x\r\n\r\n")
            .unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // wait for the stream header so the subscription exists before
        // publishing
        let mut buf = [0u8; 4096];
        let mut seen = Vec::new();
        while !seen.windows(4).any(|w| w == b"\r\n\r\n") {
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "stream closed before the SSE header");
            seen.extend_from_slice(&buf[..n]);
        }
        assert!(seen.starts_with(b"HTTP/1.1 200"));
        h.publish_event("{\"event\":\"round_phase\",\"round\":1}");
        h.publish_event("{\"event\":\"round_phase\",\"round\":2}");
        let mut text = String::from_utf8_lossy(&seen).into_owned();
        while !text.contains("\"round\":2") {
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "stream closed before both frames arrived");
            text.push_str(&String::from_utf8_lossy(&buf[..n]));
        }
        assert!(text.contains("data: {\"event\":\"round_phase\",\"round\":1}"));
        drop(s);
        // the dead subscriber is pruned on the next publish
        h.publish_event("{\"event\":\"round_phase\",\"round\":3}");
    }
}
