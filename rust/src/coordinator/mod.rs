//! The L3 coordinator — the paper's system contribution as a runnable
//! server.
//!
//! [`Trainer`] owns the model parameters, the persistent worker pool, the
//! algorithm state machine, the byte-metered transport and the metrics
//! log, and drives the synchronous round loop of Algorithm 1:
//!
//! ```text
//! per round t:
//!   broadcast θ_{t-1} (+ global mask seed)        — algorithm meters it
//!   workers: g_i = ∇L_i(θ_{t-1}) on a fresh batch — worker pool (native)
//!   Byzantine payload injection                    — attacks
//!   server: reconstruct → momentum → F(m_1..m_n)   — algorithm
//!   θ_t = θ_{t-1} − γ R^t
//!   every eval_every rounds: test accuracy, τ-crossing, Lyapunov diag
//! ```
//!
//! ## Round execution (§Perf + §Net)
//!
//! The round loop drives a [`round_transport::RoundTransport`] — "given
//! θ_{t-1}, produce this round's per-worker gradients and losses" — with
//! two implementations selected by `config: transport`:
//!
//! * **local** ([`round_transport::LocalTransport`], the oracle):
//!   gradients run on a [`pool::WorkerPool`] created **once** in
//!   [`Trainer::from_config`] and reused for every round: threads park on
//!   a channel instead of being spawned per round, workers and their
//!   reusable gradient buffers travel through the pool by move, and the
//!   steady-state loop is allocation-free. The pool size is configurable
//!   (`config: pool_size`, 0 = auto) and never changes results — each
//!   worker owns its RNG stream, so the loss trajectory, byte counters
//!   and τ-crossing are bit-identical for any thread count (pinned by
//!   `rust/tests/test_round_engine.rs`). Under PJRT the pool is disabled
//!   (the client is not `Send`) and gradients run sequentially on the
//!   main thread, with identical numerics.
//! * **tcp** ([`round_transport::TcpTransport`]): the same wire format
//!   over real sockets — n worker processes (`rosdhb join`) plus this
//!   coordinator (`rosdhb serve`), bit-identical `RunReport`s and
//!   measured traffic equal to the [`ByteMeter`] model (pinned by
//!   `rust/tests/test_transport_tcp.rs`).
//!
//! Worker panics surface as `Err` from [`Trainer::step`] rather than
//! aborting the process; a crashed or stalled *remote* worker degrades
//! into a dropped contribution.

pub mod pool;
pub mod round_transport;

use crate::aggregators::geometry::{GeoStats, RefreshPeriod};
use crate::aggregators::{self, Aggregator};
use crate::algorithms::{self, Algorithm, RoundEnv, UplinkCtx};
use crate::attacks::{self, AttackKind};
use crate::compression::payload::PayloadPlan;
use crate::compression::RandK;
use crate::checkpoint::Checkpoint;
use crate::config::{
    parse_churn, ChurnEvent, Dataset as DatasetCfg, Engine, ExperimentConfig,
};
use crate::data::{self, Dataset};
use crate::diagnostics;
use crate::metrics::{MetricsLog, RoundRecord};
use crate::model::MlpSpec;
use crate::prng::Pcg64;
use crate::telemetry::forensics::{self, SuspicionTracker, WorkerSuspicion};
use crate::telemetry::status::{StatusServer, StatusState};
use crate::telemetry::{Event, Histogram, PhaseStats, Telemetry};
use crate::tensor;
use crate::transport::downlink::{
    self, DownlinkCodec, DownlinkMode, DownlinkStats, FanoutPlan,
};
use crate::transport::evloop::ServerIo;
use crate::transport::net::NetStats;
use crate::transport::uplink::ReducePlan;
use crate::transport::{broadcast_len, ByteMeter};
#[cfg(feature = "pjrt")]
use crate::worker::PjrtEngine;
use crate::worker::{GradEngine, HonestWorker, NativeEngine};
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};
use self::pool::WorkerPool;
use self::round_transport::{LocalTransport, RoundTransport, TcpTransport};

/// Build the gradient-computing workers (honest shards first, then any
/// label-flip-poisoned Byzantine clones) and the test set, exactly as the
/// round loop will index them.
///
/// This is the single source of truth for shard assignment and per-worker
/// RNG streams: `rosdhb join` calls it too, so a remote worker process
/// rebuilds byte-identical local state from the shared config (guarded by
/// [`ExperimentConfig::wire_fingerprint`] at rendezvous).
pub fn build_training_workers(
    cfg: &ExperimentConfig,
) -> Result<(Vec<HonestWorker>, Dataset)> {
    build_training_workers_for_epoch(cfg, 0)
}

/// Epoch-aware worker derivation — the elastic-membership determinism
/// rule. Epoch 0 is the historical derivation bit for bit; every later
/// epoch re-derives the partition RNG and all per-worker streams from a
/// sub-root keyed on `(seed, epoch)` alone, so a worker joining mid-run
/// (in any slot, in any arrival order) rebuilds state identical to one
/// that was present from round 1. Nothing about membership history leaks
/// into the streams — join order can never change results.
pub fn build_training_workers_for_epoch(
    cfg: &ExperimentConfig,
    epoch: u64,
) -> Result<(Vec<HonestWorker>, Dataset)> {
    let root = Pcg64::new(cfg.seed, 0);
    let root = if epoch == 0 {
        root
    } else {
        root.derive(0x6570_6f63 /* "epoc" */, epoch, 0)
    };
    let (train, test) = load_dataset(cfg)?;
    let mut part_rng = root.derive(0x7061_7274, 0, 0);
    let shards = match crate::config::parse_partition(&cfg.partition)
        .map_err(|e| anyhow!(e))?
    {
        None => data::partition_iid(&train, cfg.n_honest, &mut part_rng),
        Some(alpha) => data::partition_dirichlet(
            &train,
            cfg.n_honest,
            alpha,
            &mut part_rng,
        ),
    };
    let mut workers: Vec<HonestWorker> = shards
        .into_iter()
        .enumerate()
        .map(|(i, s)| HonestWorker::new(i, s, &root, false))
        .collect();
    let attack = attacks::parse_spec(&cfg.attack).map_err(|e| anyhow!(e))?;
    if matches!(attack, AttackKind::LabelFlip) {
        for j in 0..cfg.n_byz {
            // each poisoned worker clones an honest shard
            let shard = workers[j % cfg.n_honest].shard.clone();
            workers.push(HonestWorker::new(
                cfg.n_honest + j,
                shard,
                &root,
                true,
            ));
        }
    }
    Ok((workers, test))
}

/// The (train, test) split named by the config — the one loading path
/// every participant shares, so coordinator and remote workers can never
/// see different data.
fn load_dataset(cfg: &ExperimentConfig) -> Result<(Dataset, Dataset)> {
    match &cfg.dataset {
        DatasetCfg::Synthetic => Ok(data::generate_synthetic_split(
            cfg.seed ^ 0xdada,
            cfg.train_size,
            cfg.test_size,
        )),
        DatasetCfg::MnistIdx(dir) => {
            data::load_mnist_idx(dir).map_err(|e| anyhow!("mnist: {e}"))
        }
    }
}

/// Test split + gradient-slot count **without** materializing worker
/// shards — the TCP coordinator never computes gradients itself (remote
/// workers rebuild their own shard from the shared config), so running
/// the partition and cloning label-flip shards would be pure waste.
fn build_eval_side(cfg: &ExperimentConfig) -> Result<(Dataset, usize)> {
    let (_train, test) = load_dataset(cfg)?;
    let attack = attacks::parse_spec(&cfg.attack).map_err(|e| anyhow!(e))?;
    let n_grad = cfg.n_honest
        + if matches!(attack, AttackKind::LabelFlip) {
            cfg.n_byz
        } else {
            0
        };
    Ok((test, n_grad))
}

/// End-of-run summary (plus the full per-round log).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algorithm: String,
    pub rounds_run: usize,
    /// First round at which test accuracy ≥ τ (None if never reached).
    pub rounds_to_tau: Option<usize>,
    /// Cumulative uplink bytes at the τ-crossing (the Fig. 1 y-axis).
    pub uplink_bytes_to_tau: Option<u64>,
    pub uplink_bytes: u64,
    /// The subset of `uplink_bytes` the coordinator itself received —
    /// equal to `uplink_bytes` under value-forwarding (and under
    /// `uplink = "aggregate"` on a flat fan-out); only the root
    /// subtrees' accumulated frames under the relay tree, where ingress
    /// drops from n·B to branching·B.
    pub coordinator_ingress_bytes: u64,
    /// The subset of `uplink_bytes` folded into accumulated frames by
    /// worker relays instead of reaching the coordinator:
    /// `uplink_bytes − coordinator_ingress_bytes` (0 under
    /// value-forwarding). The uplink mirror of
    /// [`Self::relayed_downlink_bytes`].
    pub relayed_uplink_bytes: u64,
    /// Total downlink bytes *delivered* (one copy per recipient).
    pub downlink_bytes: u64,
    /// The subset of `downlink_bytes` the coordinator itself sent —
    /// equal to `downlink_bytes` under `fanout = "flat"`, `branching/n`
    /// of it under the relay tree.
    pub coordinator_egress_bytes: u64,
    /// The subset of `downlink_bytes` delivered by worker relays rather
    /// than the coordinator: `downlink_bytes − coordinator_egress_bytes`
    /// (0 under `fanout = "flat"`). Surfaces the worker-side relayed-byte
    /// counters without a return channel — the byte model guarantees the
    /// two views agree.
    pub relayed_downlink_bytes: u64,
    /// Relay-tree dense-resync fallbacks the coordinator served (tcp
    /// transport only; 0 otherwise).
    pub relay_resyncs: u64,
    /// Workers evicted mid-run by the transport (tcp only; 0 otherwise).
    pub evictions: u64,
    /// Wall-clock histograms per round phase (broadcast / collect /
    /// aggregate / apply). Timing only — never part of a parity oracle.
    pub phases: PhaseStats,
    /// Per-worker round-trip latency histograms (tcp only; empty under
    /// the local transport). Timing only, like [`Self::phases`].
    pub worker_latency: Vec<Histogram>,
    /// Rebuild/incremental counters of the maintained pairwise geometry
    /// (`None` unless the sparse engine kept one).
    pub geometry: Option<GeoStats>,
    /// Per-worker rolling suspicion statistics (`config: forensics`;
    /// empty when forensics is off). Observation only — never feeds
    /// back into aggregation or the wire.
    pub suspicion: Vec<WorkerSuspicion>,
    pub best_acc: Option<f64>,
    pub final_loss: Option<f64>,
    pub log: MetricsLog,
}

/// The coordinator.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    /// Evaluation + sequential-path gradient engine.
    engine: Box<dyn GradEngine>,
    /// How this round's gradients are exchanged (in-process pool, or the
    /// socket runtime).
    transport: Box<dyn RoundTransport>,
    algorithm: Box<dyn Algorithm>,
    aggregator: Box<dyn Aggregator>,
    attack: AttackKind,
    pub params: Vec<f32>,
    test_set: Dataset,
    meter: ByteMeter,
    rng: Pcg64,
    pub log: MetricsLog,
    k: usize,
    /// Parsed `config: geometry_refresh` (exact-refresh period of the
    /// sparse engine's incremental pairwise geometry).
    geometry_refresh: RefreshPeriod,
    /// The uplink wire plan the config implies — also decides the dense
    /// broadcast shape (mask seed rides downlink only under the shared
    /// mask), so the trainer can meter downlink uniformly for every
    /// algorithm and transport.
    plan: PayloadPlan,
    /// Parsed `config: fanout`/`branching` — the coordinator-egress model.
    fanout: FanoutPlan,
    /// Delta-broadcast encoder (`config: downlink = "delta"`); `None`
    /// under dense downlink. Drives both the byte model (local and tcp)
    /// and the frames the TCP transport actually sends.
    downlink_codec: Option<DownlinkCodec>,
    /// Set when loss/update became non-finite; `run()` stops gracefully.
    pub diverged: bool,
    /// Parsed `config: churn` — the coordinator-local membership schedule
    /// applied at epoch boundaries.
    churn: Vec<ChurnEvent>,
    /// First completed round of this process: 0 for a fresh run, the
    /// checkpointed round after [`Self::load_checkpoint`] — `run()`
    /// resumes at `start_round + 1`.
    start_round: u64,
    /// τ-crossing memo `(round, uplink bytes)`, lifted out of `run()`'s
    /// locals so a restore can re-seed it.
    reached: Option<(usize, u64)>,
    /// Write a [`Checkpoint`] here at qualifying epoch boundaries.
    checkpoint_path: Option<PathBuf>,
    /// Checkpoint every this many epochs (`--every`, default 1).
    checkpoint_every: u64,
    /// The opening round of an epoch broadcasts the dense model even
    /// under `downlink = "delta"` — joiners have no replica history and
    /// the straight/restored runs must both restart the delta chain.
    epoch_resync: bool,
    /// Per-worker reusable gradient buffers (honest slots first, then
    /// data-level Byzantine workers).
    grad_store: Vec<Vec<f32>>,
    /// Per-worker losses for the current round.
    loss_store: Vec<f32>,
    /// Structured event journal (`config: trace_path`). Shares the TCP
    /// transport's handle so rendezvous and round events land in one
    /// file; a disabled handle (the default) reduces every emit site to
    /// a single branch.
    tel: Telemetry,
    /// Wall-clock histograms per round phase, folded into [`RunReport`].
    phases: PhaseStats,
    /// Live status endpoint (`config: status_addr`); `None` when unset.
    /// The round loop pushes a snapshot after every round and never
    /// blocks on clients.
    status: Option<StatusServer>,
    /// Rolling per-worker suspicion statistics fed by the armed
    /// forensics collector (`config: forensics`; stays empty when off).
    suspicion: SuspicionTracker,
}

impl Trainer {
    /// Build everything from a validated config, including the transport
    /// it names. With `transport = "tcp"` this **blocks** until all
    /// `n_total` workers have joined `listen_addr`.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        if cfg.transport == "tcp" {
            let (test, n_grad) = build_eval_side(cfg)?;
            let server = ServerIo::bind(&cfg.listen_addr, &cfg.io)?;
            eprintln!(
                "rosdhb[tcp]: listening on {}, waiting for {} workers \
                 (`rosdhb join --coordinator_addr {}`)",
                server.local_addr(),
                cfg.n_total(),
                server.local_addr(),
            );
            let d = MlpSpec::default().p();
            let transport = TcpTransport::rendezvous_io(server, cfg, d)?;
            return Self::with_transport_and_test_set(
                cfg,
                Box::new(transport),
                test,
                n_grad,
            );
        }
        let (workers, test) = build_training_workers(cfg)?;
        let n_grad = workers.len();
        // --- persistent gradient pool (native only: the PJRT client is
        // not Send). Created once here, reused for every round.
        let pool = if cfg.engine == Engine::Native {
            let size = if cfg.pool_size > 0 {
                cfg.pool_size
            } else {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
                    .min(n_grad.max(1))
            };
            Some(WorkerPool::new(size, MlpSpec::default(), cfg.batch.max(1)))
        } else {
            None
        };
        let transport = LocalTransport::new(workers, pool);
        Self::with_transport_and_test_set(cfg, Box::new(transport), test, n_grad)
    }

    /// Build a trainer resuming from `restore` — the construction path
    /// of `serve/train --restore`. The checkpoint is read *before* the
    /// transport comes up so a TCP coordinator rendezvouses only the
    /// slots that were active at save time: a slot vacated by churn or a
    /// graceful leave stays vacant across the restore instead of
    /// blocking rendezvous on (or being silently re-filled by) a worker
    /// the checkpointed run no longer had.
    pub fn from_config_restored(
        cfg: &ExperimentConfig,
        restore: &Path,
    ) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let ck = Checkpoint::read(restore, cfg.wire_fingerprint())
            .map_err(|e| anyhow!(e))?;
        let mut trainer = if cfg.transport == "tcp" {
            let (test, n_grad) = build_eval_side(cfg)?;
            let server = ServerIo::bind(&cfg.listen_addr, &cfg.io)?;
            let n_active = if ck.membership.len() == cfg.n_total() {
                ck.membership.iter().filter(|s| s.active).count()
            } else {
                cfg.n_total()
            };
            eprintln!(
                "rosdhb[tcp]: listening on {}, waiting for {} workers \
                 (`rosdhb join --coordinator_addr {}`)",
                server.local_addr(),
                n_active,
                server.local_addr(),
            );
            let d = MlpSpec::default().p();
            let transport = TcpTransport::rendezvous_restored_io(
                server,
                cfg,
                d,
                &ck.membership,
            )?;
            Self::with_transport_and_test_set(
                cfg,
                Box::new(transport),
                test,
                n_grad,
            )?
        } else {
            Self::from_config(cfg)?
        };
        trainer.apply_checkpoint(&ck)?;
        Ok(trainer)
    }

    /// Build a trainer around an externally constructed transport (the
    /// loopback tests pre-bind an ephemeral port this way).
    pub fn with_transport(
        cfg: &ExperimentConfig,
        transport: Box<dyn RoundTransport>,
    ) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let (workers, test) = build_training_workers(cfg)?;
        let n_grad = workers.len();
        Self::with_transport_and_test_set(cfg, transport, test, n_grad)
    }

    fn with_transport_and_test_set(
        cfg: &ExperimentConfig,
        transport: Box<dyn RoundTransport>,
        test: Dataset,
        n_grad: usize,
    ) -> Result<Self> {
        // --- engine
        let mut engine: Box<dyn GradEngine> = match cfg.engine {
            Engine::Native => {
                Box::new(NativeEngine::new(MlpSpec::default(), cfg.batch.max(1)))
            }
            #[cfg(feature = "pjrt")]
            Engine::Pjrt => Box::new(PjrtEngine::load(&cfg.artifacts_dir)?),
            #[cfg(not(feature = "pjrt"))]
            Engine::Pjrt => {
                return Err(anyhow!(
                    "engine = pjrt is not available in this build: \
                     recompile with `--features pjrt` (needs the external \
                     xla crate — see rust/README.md)"
                ))
            }
        };
        let d = engine.p();

        let attack = attacks::parse_spec(&cfg.attack).map_err(|e| anyhow!(e))?;
        let aggregator = aggregators::parse_spec(&cfg.aggregator, cfg.n_byz)
            .map_err(|e| anyhow!(e))?;
        let algorithm = algorithms::build(cfg, d);
        let params = engine.init_params(cfg.seed ^ 0x1a17)?;
        let k = RandK::from_frac(d, cfg.k_frac).k;
        let plan = PayloadPlan::from_config(cfg, d);
        let fanout = FanoutPlan::parse(&cfg.fanout, cfg.branching)
            .map_err(|e| anyhow!(e))?;
        let downlink_codec =
            match DownlinkMode::parse(&cfg.downlink).map_err(|e| anyhow!(e))? {
                DownlinkMode::Dense => None,
                DownlinkMode::Delta => {
                    Some(DownlinkCodec::new(d, k, cfg.seed, cfg.beta))
                }
            };

        // --- telemetry: the TCP transport opened the journal at
        // rendezvous (so admissions/rejections are already in it) —
        // share that handle; a local run opens its own on the same path.
        let tel = {
            let t = transport.telemetry();
            if t.enabled() || cfg.trace_path.is_empty() {
                t
            } else {
                Telemetry::to_path(&cfg.trace_path).map_err(|e| {
                    anyhow!("trace_path {:?}: {e}", cfg.trace_path)
                })?
            }
        };
        tel.install_panic_hook();
        let status = if cfg.status_addr.is_empty() {
            None
        } else {
            let srv = StatusServer::bind(&cfg.status_addr).map_err(|e| {
                anyhow!("status_addr {:?}: {e}", cfg.status_addr)
            })?;
            let handle = srv.handle();
            handle.set_history_depth(cfg.status_history);
            if tel.enabled() {
                // serve `/clock` from the journal's clock so worker
                // offset probes and journal timestamps share one base
                let clock_tel = tel.clone();
                handle.set_clock_source(std::sync::Arc::new(move || {
                    clock_tel.local_now_us()
                }));
                // forward every journaled event to `/events` streams —
                // observation fan-out only, never the data path
                let tap_handle = handle.clone();
                tel.set_event_tap(Some(std::sync::Arc::new(
                    move |line: &str| tap_handle.publish_event(line),
                )));
            }
            eprintln!("rosdhb[status]: serving on {}", srv.local_addr());
            Some(srv)
        };

        Ok(Trainer {
            cfg: cfg.clone(),
            engine,
            transport,
            algorithm,
            aggregator,
            attack,
            params,
            test_set: test,
            meter: ByteMeter::new(cfg.n_total()),
            // definitionally crate::prng::round_stream(cfg.seed) — the
            // stream remote CompressorStates re-derive client-side
            rng: crate::prng::round_stream(cfg.seed),
            log: MetricsLog::default(),
            k,
            geometry_refresh: RefreshPeriod::parse(&cfg.geometry_refresh)
                .map_err(|e| anyhow!(e))?,
            plan,
            fanout,
            downlink_codec,
            diverged: false,
            churn: parse_churn(&cfg.churn).map_err(|e| anyhow!(e))?,
            start_round: 0,
            reached: None,
            checkpoint_path: None,
            checkpoint_every: 1,
            epoch_resync: false,
            grad_store: vec![vec![0f32; d]; n_grad],
            loss_store: vec![0f32; n_grad],
            tel,
            phases: PhaseStats::default(),
            status,
            suspicion: SuspicionTracker::default(),
        })
    }

    /// Robustness coefficient bound of the configured aggregator at (n,f).
    pub fn kappa_bound(&self) -> f64 {
        self.aggregator
            .kappa(self.cfg.n_total(), self.cfg.n_byz)
    }

    /// Compute this round's gradients into `grad_store`/`loss_store`
    /// through the configured transport. Worker panics and engine errors
    /// come back as `Err` (never an abort); remote-worker failures
    /// degrade into dropped contributions inside the transport.
    /// `dense_resync` (the opening round of an epoch) suppresses the
    /// delta-downlink frame: the broadcast carries the dense model so
    /// fresh joiners and surviving replicas alike re-anchor on θ.
    fn compute_gradients(&mut self, t: u64, dense_resync: bool) -> Result<()> {
        let downlink = if dense_resync {
            None
        } else {
            self.downlink_codec.as_ref().map(|c| c.frame(t))
        };
        self.transport.exchange(
            t,
            self.engine.as_mut(),
            &self.params,
            self.cfg.batch,
            downlink,
            &mut self.grad_store,
            &mut self.loss_store,
        )
    }

    /// Delta/dense broadcast counters of the downlink codec (`downlink =
    /// "delta"` only) — the tests' handle on "a carry-law break falls
    /// back to a dense frame".
    pub fn downlink_stats(&self) -> Option<DownlinkStats> {
        self.downlink_codec.as_ref().map(|c| c.stats)
    }

    /// Measured socket traffic (tcp transport only).
    pub fn net_stats(&self) -> Option<NetStats> {
        self.transport.net_stats()
    }

    /// The trainer's telemetry handle — the transport's journal under
    /// tcp, its own under local; a disabled handle when `trace_path` is
    /// empty.
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    /// Bound address of the live status endpoint (`None` unless
    /// `config: status_addr` is set) — tests bind `"127.0.0.1:0"` and
    /// read the real port back here.
    pub fn status_addr(&self) -> Option<std::net::SocketAddr> {
        self.status.as_ref().map(|s| s.local_addr())
    }

    /// Wall-clock per-phase histograms accumulated so far.
    pub fn phase_stats(&self) -> &PhaseStats {
        &self.phases
    }

    /// Record one round phase's duration into the histogram set and the
    /// event journal.
    fn note_phase(&mut self, t: u64, phase: &'static str, d: Duration) {
        let hist = match phase {
            "broadcast" => &mut self.phases.broadcast,
            "collect" => &mut self.phases.collect,
            "aggregate" => &mut self.phases.aggregate,
            _ => &mut self.phases.apply,
        };
        hist.record(d);
        self.tel.emit(|| Event::RoundPhase {
            round: t,
            phase,
            micros: d.as_micros() as u64,
        });
    }

    /// Refresh the live status snapshot after round `t` (no-op unless
    /// `status_addr` is set). The endpoint thread serves whatever the
    /// latest call published — the round loop never blocks on clients.
    fn push_status(&mut self, t: u64) {
        let Some(srv) = &self.status else { return };
        let er = self.cfg.epoch_rounds as u64;
        let health = self.transport.health();
        let state = StatusState {
            algorithm: self.algorithm.name().to_string(),
            rounds_total: self.cfg.rounds as u64,
            round: t,
            epoch: if er > 0 { (t - 1) / er } else { 0 },
            slots: health.as_ref().map_or_else(Vec::new, |h| h.slots.clone()),
            net: health.as_ref().map(|h| h.net),
            uplink_bytes: self.meter.uplink,
            coordinator_ingress_bytes: self.meter.coordinator_ingress,
            relayed_uplink_bytes: self
                .meter
                .uplink
                .saturating_sub(self.meter.coordinator_ingress),
            downlink_bytes: self.meter.downlink,
            coordinator_egress_bytes: self.meter.coordinator_egress,
            relayed_downlink_bytes: self
                .meter
                .downlink
                .saturating_sub(self.meter.coordinator_egress),
            relay_resyncs: health.as_ref().map_or(0, |h| h.relay_resyncs),
            evictions: health.as_ref().map_or(0, |h| h.evictions),
            lyapunov: self.log.rows.last().and_then(|r| r.lyapunov),
            trace_events: self.tel.events_recorded(),
            geometry: self
                .algorithm
                .geometry_stats()
                .map(|g| (g.rebuilds, g.incrementals)),
            suspicion: self.suspicion.scores(),
            workers: Default::default(),
        };
        let handle = srv.handle();
        handle.update(|s| {
            // worker-pushed side-channel stats outlive any one round —
            // carry them across the wholesale snapshot replacement
            let workers = std::mem::take(&mut s.workers);
            *s = state;
            s.workers = workers;
        });
        handle.push_history();
    }

    /// Rebuild/incremental counters of the algorithm's maintained
    /// pairwise geometry (sparse engine + geometry-backed aggregator
    /// only) — lets tests pin "no O(n²d) distance recompute outside
    /// refresh rounds".
    pub fn geometry_stats(&self) -> Option<GeoStats> {
        self.algorithm.geometry_stats()
    }

    /// Per-worker rolling suspicion summary accumulated by the armed
    /// forensics collector so far (empty unless `config: forensics`).
    pub fn suspicion_summary(&self) -> Vec<WorkerSuspicion> {
        self.suspicion.summary()
    }

    /// Fold one round's armed forensics capture into the rolling
    /// suspicion statistics and the event journal.
    fn note_forensics(&mut self, t: u64, rf: &forensics::RoundForensics) {
        self.suspicion.observe(rf, self.cfg.n_total());
        self.tel.emit(|| Event::AggForensics {
            round: t,
            selected: rf.selected.as_ref().map_or_else(Vec::new, |s| {
                s.iter().map(|&i| i as u32).collect()
            }),
            neighbor_rows: rf.neighbors.as_ref().map_or(0, |rows| {
                rows.iter().filter(|r| !r.is_empty()).count() as u64
            }),
            weiszfeld_iters: rf.weiszfeld.map_or(0, |(i, _)| i as u64),
            weiszfeld_residual: rf.weiszfeld.map_or(0.0, |(_, r)| r),
            trim_cols: rf.trim_inclusion.as_ref().map_or(0, |&(_, c)| c),
        });
        let suspicion = self.suspicion.scores();
        self.tel.emit(move || Event::SuspicionSnapshot {
            round: t,
            suspicion,
        });
    }

    /// Release transport resources (tcp: tell workers the run is over).
    /// Also happens on drop.
    pub fn shutdown_transport(&mut self) {
        self.transport.shutdown();
    }

    /// One synchronous round; returns (mean honest loss, ‖R‖).
    pub fn step(&mut self, t: u64) -> Result<(f64, f64)> {
        let nh = self.cfg.n_honest;
        // An epoch's opening round broadcasts dense regardless of the
        // downlink mode — metered and transmitted alike.
        let resync = std::mem::take(&mut self.epoch_resync);
        // Downlink byte model (owned here, not by the algorithm: the
        // broadcast shape is a transport concern — dense model + optional
        // mask seed, or the delta codec's frame — and the fan-out plan
        // splits delivered bytes from coordinator egress).
        let n = self.cfg.n_total();
        let frame_len = match &self.downlink_codec {
            Some(codec) if !resync => codec.frame_len(t),
            _ => broadcast_len(
                self.params.len(),
                matches!(self.plan, PayloadPlan::SparseGlobal { .. }),
            ),
        };
        self.meter.record_broadcast_fanout(
            frame_len,
            n,
            self.fanout.direct_count(n),
        );
        let exchange_start = Instant::now();
        self.compute_gradients(t, resync)?;
        let exchange = exchange_start.elapsed();
        // The TCP transport splits broadcast/collect internally; the
        // local transport's whole in-process exchange books as collect.
        match self.transport.take_phase_durations() {
            Some((b, c)) => {
                self.note_phase(t, "broadcast", b);
                self.note_phase(t, "collect", c);
            }
            None => self.note_phase(t, "collect", exchange),
        }
        let mut loss_sum = 0.0f64;
        for &l in &self.loss_store[..nh] {
            loss_sum += l as f64;
        }
        let mean_loss = loss_sum / nh as f64;

        let aggregate_start = Instant::now();
        let (honest_grads, byz_grads) = self.grad_store.split_at(nh);
        // Aggregated-uplink context: the logical reduce plan spans this
        // round's active gradient slots. Over tcp the transport already
        // folded the round's AGG frames (`take_aggregated`); under the
        // local transport the algorithm runs the oracle fold through
        // the identical plan recursion.
        let aggregate_plan = if self.cfg.uplink == "aggregate" {
            Some(ReducePlan::new(
                self.cfg.branching,
                &self.transport.active_gradient_slots(),
            ))
        } else {
            None
        };
        let aggregated = if aggregate_plan.is_some()
            && self.cfg.transport == "tcp"
        {
            Some(self.transport.take_aggregated())
        } else {
            None
        };
        let physical_tree = matches!(self.fanout, FanoutPlan::Tree { .. });
        let mut env = RoundEnv {
            d: self.params.len(),
            n_honest: self.cfg.n_honest,
            n_byz: self.cfg.n_byz,
            seed: self.cfg.seed,
            k: self.k,
            beta: self.cfg.beta,
            aggregator: self.aggregator.as_ref(),
            geometry_refresh: self.geometry_refresh,
            attack: &self.attack,
            meter: &mut self.meter,
            rng: &mut self.rng,
            // TCP under a non-dense wire plan hands the algorithm the
            // typed payloads the workers put on the wire; the local
            // transport leaves this None and the algorithm compresses
            // the dense gradients itself (identical results — workers
            // derive the same per-(round, worker) streams).
            payloads: self.transport.round_payloads(),
            uplink: match &aggregate_plan {
                None => UplinkCtx::Forward,
                Some(plan) => match aggregated {
                    Some(total) => UplinkCtx::Wire {
                        plan,
                        total,
                        physical_tree,
                    },
                    None => UplinkCtx::Local {
                        plan,
                        physical_tree,
                    },
                },
            },
        };
        // Aggregation forensics: arm the thread-local collector around
        // the aggregation call so the rules can report what they saw
        // (scores, selected sets, trim inclusion, distances). Strictly
        // an observer — arming never changes a single aggregated bit.
        let forensics_on = self.cfg.forensics
            && (self.tel.enabled() || self.status.is_some());
        if forensics_on {
            forensics::arm();
        }
        let mut update = self
            .algorithm
            .round(t, honest_grads, byz_grads, &mut env);
        if forensics_on {
            if let Some(rf) = forensics::disarm() {
                self.note_forensics(t, &rf);
            }
        }
        if let Some(codec) = &mut self.downlink_codec {
            // decide how round t+1's broadcast describes R^t — on the
            // raw aggregate, before clipping (workers clip locally
            // through the same shared step law)
            codec.note_update(t, &update);
        }

        // Lyapunov diagnostics (against the sampled honest mean gradient).
        let lyapunov = if self.cfg.lyapunov {
            self.algorithm.momenta().map(|m| {
                let refs: Vec<&[f32]> = m[..self.cfg.n_honest]
                    .iter()
                    .map(|v| v.as_slice())
                    .collect();
                let grefs: Vec<&[f32]> =
                    honest_grads.iter().map(|g| g.as_slice()).collect();
                let gh = tensor::mean(&grefs);
                let snap = diagnostics::snapshot(&refs, &gh);
                (snap.deviation_sq, snap.drift)
            })
        } else {
            None
        };
        self.note_phase(t, "aggregate", aggregate_start.elapsed());

        // θ_t = θ_{t-1} − γ_t·clip(R^t) — through the one shared step law
        // (`transport::downlink::apply_update`), which delta-downlink
        // worker replicas run verbatim: the two sides cannot drift.
        let apply_start = Instant::now();
        downlink::apply_update(
            &mut self.params,
            &mut update,
            self.cfg.gamma,
            self.cfg.gamma_decay,
            self.cfg.clip,
            t,
        );
        self.note_phase(t, "apply", apply_start.elapsed());
        let update_norm = tensor::norm(&update);
        if !update_norm.is_finite() || !mean_loss.is_finite() {
            self.diverged = true;
        }

        // bookkeeping row (test_acc filled by run())
        self.log.push(RoundRecord {
            round: t as usize,
            train_loss: mean_loss,
            update_norm,
            test_acc: None,
            uplink_bytes: self.meter.uplink,
            downlink_bytes: self.meter.downlink,
            lyapunov,
        });
        self.push_status(t);
        Ok((mean_loss, update_norm))
    }

    /// Write a [`Checkpoint`] to `path` at every `every`-th epoch
    /// boundary (requires `config: epoch_rounds > 0`; `run()` errors
    /// otherwise the first time a write would be due).
    pub fn set_checkpoint(&mut self, path: impl Into<PathBuf>, every: u64) {
        self.checkpoint_path = Some(path.into());
        self.checkpoint_every = every.max(1);
    }

    /// Resume from a checkpoint written by a previous process: restore
    /// θ, the round-stream RNG, byte meters, metrics rows, slot
    /// membership, the algorithm's per-worker state and the
    /// observability counters, then arrange for `run()` to continue at
    /// the next round. The restored trajectory is bit-identical to never
    /// having stopped.
    ///
    /// The transport must already hold the checkpoint's membership shape
    /// — a TCP trainer restoring a run with vacated slots should be
    /// built through [`Self::from_config_restored`] (or
    /// [`TcpTransport::rendezvous_restored`]), which rendezvouses only
    /// the active slots; with a full rendezvous behind it, this releases
    /// the workers that joined checkpoint-vacant slots.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let ck = Checkpoint::read(path, self.cfg.wire_fingerprint())
            .map_err(|e| anyhow!(e))?;
        self.apply_checkpoint(&ck)
    }

    /// The state-application half of [`Self::load_checkpoint`].
    fn apply_checkpoint(&mut self, ck: &Checkpoint) -> Result<()> {
        let er = self.cfg.epoch_rounds as u64;
        if er == 0 {
            return Err(anyhow!(
                "restore requires `epoch_rounds > 0`: checkpoints exist \
                 only at epoch boundaries"
            ));
        }
        if ck.round == 0 || ck.round % er != 0 {
            return Err(anyhow!(
                "checkpoint round {} is not an epoch boundary of \
                 epoch_rounds = {er}",
                ck.round
            ));
        }
        if ck.params.len() != self.params.len() {
            return Err(anyhow!(
                "checkpoint carries {} parameters, model has {}",
                ck.params.len(),
                self.params.len()
            ));
        }
        self.params.copy_from_slice(&ck.params);
        let (state, inc, id) = ck.rng;
        self.rng = Pcg64::from_parts(state, inc, id);
        self.meter = ck.meter.clone();
        self.reached = ck.reached.map(|(r, b)| (r as usize, b));
        self.diverged = ck.diverged;
        self.log.rows = ck.rows.clone();
        self.transport.restore_membership(&ck.membership)?;
        self.algorithm
            .load_state(&ck.algo_state)
            .map_err(|e| anyhow!(e))?;
        if let (Some(codec), Some(stats)) =
            (self.downlink_codec.as_mut(), ck.downlink)
        {
            codec.stats = stats;
        }
        if let Some(geo) = ck.geo {
            self.algorithm.preseed_geometry_stats(geo);
        }
        if let Some(net) = ck.net {
            self.transport.preseed_net_stats(net);
        }
        self.start_round = ck.round;
        Ok(())
    }

    /// Serialize the full post-round-`t` state (an epoch boundary) to
    /// `path`, atomically.
    fn save_checkpoint(&self, t: u64, path: &Path) -> Result<()> {
        let mut algo_state = Vec::new();
        self.algorithm.save_state(&mut algo_state);
        let ck = Checkpoint {
            fingerprint: self.cfg.wire_fingerprint(),
            round: t,
            params: self.params.clone(),
            rng: self.rng.state_parts(),
            meter: self.meter.clone(),
            reached: self.reached.map(|(r, b)| (r as u64, b)),
            diverged: self.diverged,
            rows: self.log.rows.clone(),
            algo_state,
            downlink: self.downlink_stats(),
            geo: self.geometry_stats(),
            net: self.transport.net_stats(),
            membership: self.transport.membership(),
        };
        ck.write(path).map_err(|e| anyhow!(e))
    }

    /// The opening boundary of the epoch whose first round is `t`:
    /// apply membership churn through the transport (leaves, rendezvous
    /// re-fills, suspension re-admissions), zero the per-slot algorithm
    /// state of changed slots, restart the delta-downlink chain and mark
    /// round `t`'s broadcast as a dense re-sync. Runs identically on a
    /// straight run and on one restored from a checkpoint — bit-parity
    /// depends on both sides invalidating the same derived caches here.
    fn epoch_boundary(&mut self, t: u64) -> Result<()> {
        let epoch = (t - 1) / self.cfg.epoch_rounds as u64;
        self.tel.emit(|| Event::EpochTransition { epoch, round: t });
        let changed =
            self.transport
                .epoch_boundary(epoch, &self.churn, &self.cfg)?;
        self.algorithm.on_epoch_boundary(&changed);
        if let Some(codec) = &mut self.downlink_codec {
            codec.reset();
        }
        self.epoch_resync = true;
        Ok(())
    }

    /// Current test accuracy.
    pub fn evaluate(&mut self) -> Result<f64> {
        self.engine.accuracy(&self.params, &self.test_set)
    }

    /// Fresh honest batch gradients at the current model (diagnostics /
    /// (G,B) estimation; does not advance training state). Requires the
    /// local transport.
    pub fn probe_honest_gradients(&mut self) -> Result<Vec<Vec<f32>>> {
        self.transport.probe_honest(
            self.engine.as_mut(),
            &self.params,
            self.cfg.batch,
            self.cfg.n_honest,
        )
    }

    /// Run the full loop per the config; returns the report. Resumes at
    /// `start_round + 1` after [`Self::load_checkpoint`] — the first
    /// iteration then immediately processes the epoch boundary, exactly
    /// where the straight run would process it.
    pub fn run(&mut self) -> Result<RunReport> {
        let er = self.cfg.epoch_rounds as u64;
        for t in (self.start_round + 1)..=self.cfg.rounds as u64 {
            if er > 0 && t > 1 && (t - 1) % er == 0 {
                self.epoch_boundary(t)?;
            }
            self.step(t)?;
            if self.diverged {
                eprintln!(
                    "rosdhb: run diverged at round {t} (non-finite loss/update) — stopping"
                );
                break;
            }
            if t as usize % self.cfg.eval_every == 0
                || t as usize == self.cfg.rounds
            {
                let acc = self.evaluate()?;
                if let Some(row) = self.log.rows.last_mut() {
                    row.test_acc = Some(acc);
                }
                if acc >= self.cfg.tau && self.reached.is_none() {
                    self.reached = Some((t as usize, self.meter.uplink));
                    if self.cfg.stop_at_tau {
                        break;
                    }
                }
            }
            if let Some(path) = &self.checkpoint_path {
                if er == 0 {
                    return Err(anyhow!(
                        "--checkpoint requires `epoch_rounds > 0`: \
                         checkpoints are written at epoch boundaries"
                    ));
                }
                if t % er == 0 && (t / er) % self.checkpoint_every == 0 {
                    let path = path.clone();
                    self.save_checkpoint(t, &path)?;
                    self.tel.emit(|| Event::CheckpointWritten {
                        round: t,
                        path: path.display().to_string(),
                    });
                }
            }
        }
        if let Some(path) = &self.cfg.csv_out {
            self.log.save_csv(path)?;
        }
        let reached = self.reached;
        self.tel.flush();
        let health = self.transport.health();
        Ok(RunReport {
            algorithm: self.algorithm.name().to_string(),
            rounds_run: self.log.rows.len(),
            rounds_to_tau: reached.map(|(r, _)| r),
            uplink_bytes_to_tau: reached.map(|(_, b)| b),
            uplink_bytes: self.meter.uplink,
            coordinator_ingress_bytes: self.meter.coordinator_ingress,
            relayed_uplink_bytes: self
                .meter
                .uplink
                .saturating_sub(self.meter.coordinator_ingress),
            downlink_bytes: self.meter.downlink,
            coordinator_egress_bytes: self.meter.coordinator_egress,
            relayed_downlink_bytes: self
                .meter
                .downlink
                .saturating_sub(self.meter.coordinator_egress),
            relay_resyncs: health.as_ref().map_or(0, |h| h.relay_resyncs),
            evictions: health.as_ref().map_or(0, |h| h.evictions),
            phases: self.phases.clone(),
            worker_latency: self
                .transport
                .worker_latency()
                .map_or_else(Vec::new, |h| h.to_vec()),
            geometry: self.geometry_stats(),
            suspicion: self.suspicion.summary(),
            best_acc: self.log.best_acc(),
            final_loss: self.log.final_loss(),
            log: self.log.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reach into the in-process transport (all tests here use it).
    fn local(t: &mut Trainer) -> &mut LocalTransport {
        t.transport
            .as_local_mut()
            .expect("tests run on the local transport")
    }

    fn tiny_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default_mnist_like();
        c.train_size = 600;
        c.test_size = 200;
        c.rounds = 30;
        c.eval_every = 10;
        c.n_honest = 4;
        c.n_byz = 1;
        c.batch = 30;
        c.gamma = 0.2;
        c.k_frac = 0.1;
        c.stop_at_tau = false;
        c.aggregator = "cwtm".into();
        c
    }

    #[test]
    fn trainer_builds_and_steps() {
        let mut t = Trainer::from_config(&tiny_cfg()).unwrap();
        let (l1, _) = t.step(1).unwrap();
        let (l2, _) = t.step(2).unwrap();
        assert!(l1.is_finite() && l2.is_finite());
        assert!(t.meter.uplink > 0 && t.meter.downlink > 0);
    }

    #[test]
    fn training_reduces_loss_under_attack() {
        let mut cfg = tiny_cfg();
        cfg.rounds = 120;
        cfg.attack = "alie".into();
        cfg.aggregator = "nnm+cwtm".into();
        let mut t = Trainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        let first = report.log.rows.first().unwrap().train_loss;
        let last = report.final_loss.unwrap();
        assert!(
            last < 0.8 * first,
            "loss should fall: {first} -> {last}"
        );
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let cfg = tiny_cfg();
        let r1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let r2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(r1.final_loss, r2.final_loss);
        assert_eq!(r1.uplink_bytes, r2.uplink_bytes);
    }

    #[test]
    fn labelflip_builds_poisoned_workers() {
        let mut cfg = tiny_cfg();
        cfg.attack = "labelflip".into();
        cfg.n_byz = 2;
        let mut t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(local(&mut t).workers.len(), cfg.n_honest + 2);
        assert!(local(&mut t).workers[cfg.n_honest..]
            .iter()
            .all(|w| w.as_ref().unwrap().poisoned));
        t.step(1).unwrap();
    }

    #[test]
    fn lyapunov_rows_populated_when_enabled() {
        let mut cfg = tiny_cfg();
        cfg.lyapunov = true;
        cfg.rounds = 3;
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.step(1).unwrap();
        assert!(t.log.rows[0].lyapunov.is_some());
        let (dev, drift) = t.log.rows[0].lyapunov.unwrap();
        assert!(dev.is_finite() && drift.is_finite());
    }

    #[test]
    fn kappa_bound_reflects_aggregator() {
        let mut cfg = tiny_cfg();
        cfg.aggregator = "mean".into();
        let t = Trainer::from_config(&cfg).unwrap();
        assert!(t.kappa_bound().is_infinite());
        cfg.aggregator = "nnm+cwtm".into();
        let t = Trainer::from_config(&cfg).unwrap();
        assert!(t.kappa_bound().is_finite());
    }

    #[test]
    fn pooled_and_sequential_grads_agree() {
        // dropping the pool forces the sequential path, which must
        // reproduce the pooled path bit-for-bit — same RNG streams per
        // worker, the thread count is pure mechanics.
        let cfg = tiny_cfg();
        let mut par = Trainer::from_config(&cfg).unwrap();
        let mut seq = Trainer::from_config(&cfg).unwrap();
        local(&mut seq).pool = None;
        for t in 1..=5 {
            let (lp, up) = par.step(t).unwrap();
            let (ls, us) = seq.step(t).unwrap();
            assert_eq!(lp, ls, "round {t} loss");
            assert_eq!(up, us, "round {t} update norm");
        }
        assert_eq!(par.params, seq.params);
    }

    #[test]
    fn pool_size_does_not_change_results() {
        let mut c1 = tiny_cfg();
        c1.pool_size = 1;
        let mut c3 = tiny_cfg();
        c3.pool_size = 3;
        let mut t1 = Trainer::from_config(&c1).unwrap();
        let mut t3 = Trainer::from_config(&c3).unwrap();
        for t in 1..=4 {
            let (l1, u1) = t1.step(t).unwrap();
            let (l3, u3) = t3.step(t).unwrap();
            assert_eq!(l1, l3, "round {t} loss");
            assert_eq!(u1, u3, "round {t} update norm");
        }
        assert_eq!(t1.params, t3.params);
    }

    #[test]
    fn worker_panic_surfaces_as_error_not_abort() {
        let mut t = Trainer::from_config(&tiny_cfg()).unwrap();
        {
            // empty shard => sample_batch asserts => panic inside the pool
            let w = local(&mut t).workers[0].as_mut().unwrap();
            w.shard.images.clear();
            w.shard.labels.clear();
        }
        let err = t.step(1).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        // every worker slot survived the failed round
        assert!(local(&mut t).workers.iter().all(|w| w.is_some()));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_engine_without_feature_is_a_clear_runtime_error() {
        let mut cfg = tiny_cfg();
        cfg.engine = Engine::Pjrt;
        let err = Trainer::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn clip_caps_update_norm() {
        let mut cfg = tiny_cfg();
        cfg.clip = 1e-3;
        cfg.rounds = 3;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let p0 = t.params.clone();
        t.step(1).unwrap();
        let moved: f64 = p0
            .iter()
            .zip(&t.params)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            moved <= (cfg.clip * cfg.gamma) as f64 * 1.001,
            "moved {moved}"
        );
    }

    #[test]
    fn gamma_decay_shrinks_steps() {
        let mut cfg = tiny_cfg();
        cfg.gamma_decay = 0.5;
        cfg.attack = "none".into();
        cfg.n_byz = 0;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let p0 = t.params.clone();
        t.step(1).unwrap();
        let d1: f64 = crate::tensor::dist_sq(&p0, &t.params).sqrt();
        for r in 2..=8 {
            t.step(r).unwrap();
        }
        let p8 = t.params.clone();
        t.step(9).unwrap();
        let d9: f64 = crate::tensor::dist_sq(&p8, &t.params).sqrt();
        // after 8 halvings the step is ~256x smaller (modulo momentum)
        assert!(d9 < d1 * 0.1, "d1={d1} d9={d9}");
    }

    #[test]
    fn gamma_decay_survives_huge_round_indices() {
        // regression: powi(t as i32) wrapped for t > i32::MAX and could
        // turn the decay into a blow-up; the f64/clamped path must stay
        // finite and monotone at the extremes.
        let mut cfg = tiny_cfg();
        cfg.gamma_decay = 0.999_999;
        cfg.rounds = 1;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let (loss, norm) = t.step(u64::MAX).unwrap();
        assert!(loss.is_finite() && norm.is_finite());
        let moved = t
            .params
            .iter()
            .zip(&Trainer::from_config(&cfg).unwrap().params)
            .map(|(a, b)| ((a - b) as f64).abs())
            .fold(0.0f64, f64::max);
        // decay^(2^32) underflows to ~0: the step must be ~zero, never a
        // wrapped-exponent explosion.
        assert!(moved < 1e-3, "moved {moved}");
    }

    #[test]
    fn bytes_scale_with_k_frac() {
        let mut a = tiny_cfg();
        a.k_frac = 0.01;
        a.rounds = 5;
        let mut b = a.clone();
        b.k_frac = 1.0;
        let ra = Trainer::from_config(&a).unwrap().run().unwrap();
        let rb = Trainer::from_config(&b).unwrap().run().unwrap();
        assert!(
            ra.uplink_bytes * 20 < rb.uplink_bytes,
            "k/d=0.01 uplink {} vs dense {}",
            ra.uplink_bytes,
            rb.uplink_bytes
        );
    }
}
