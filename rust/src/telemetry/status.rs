//! The live coordinator status endpoint (`serve --status_addr <addr>`).
//!
//! A [`StatusServer`] binds one read-only TCP listener and answers
//! *every* connection with a single JSON snapshot of the run — epoch
//! and round, per-slot membership with the RTT/jitter estimates of
//! [`transport::monitor`][crate::transport::monitor], cumulative byte
//! meters (both the modeled [`ByteMeter`][crate::transport::ByteMeter]
//! view and the measured
//! [`NetStats`][crate::transport::net::NetStats]), resync/eviction
//! counts, and the latest Lyapunov snapshot when the diagnostic is on.
//! The reply is a minimal `HTTP/1.1 200` with `Content-Length`, so
//! `curl <addr>` works, as does a bare `nc`.
//!
//! The endpoint is **observer-only and one-way**: the request body is
//! ignored, nothing here can mutate the run, and the listener lives on
//! its own thread driven by [`transport::poller`][crate::transport::poller]
//! — the trainer only
//! ever *pushes* a fresh [`StatusState`] into the shared cell at the
//! end of each round, so the round loop never blocks on a slow (or
//! malicious) status client.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::transport::monitor::SlotHealth;
use crate::transport::net::NetStats;
use crate::transport::poller::Poller;
use crate::util::json::Json;

use std::collections::BTreeMap;

/// The snapshot served to each connection. The trainer overwrites it
/// once per round; serving renders whatever was last pushed.
#[derive(Clone, Debug, Default)]
pub struct StatusState {
    pub algorithm: String,
    /// Rounds the run will attempt (`config: rounds`).
    pub rounds_total: u64,
    /// Last completed round (0 until the first round finishes).
    pub round: u64,
    pub epoch: u64,
    /// Per-slot membership + monitor estimates (empty for the local
    /// transport, which has no sockets).
    pub slots: Vec<SlotHealth>,
    /// Measured socket counters (`None` for the local transport).
    pub net: Option<NetStats>,
    /// Modeled byte-meter view — comparable across transports.
    pub uplink_bytes: u64,
    /// The subset of `uplink_bytes` the coordinator itself received —
    /// smaller than `uplink_bytes` only under `uplink = "aggregate"`
    /// on a relay tree, where interior relays fold their subtrees.
    pub coordinator_ingress_bytes: u64,
    /// Ingress-minus-uplink mirror of `relayed_downlink_bytes`: bytes
    /// worker relays folded into accumulated frames (0 under
    /// value-forwarding).
    pub relayed_uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub coordinator_egress_bytes: u64,
    /// Delivered-minus-egress: bytes the relay tree moved for the
    /// coordinator (0 under flat fan-out).
    pub relayed_downlink_bytes: u64,
    /// RESYNC frames the coordinator absorbed.
    pub relay_resyncs: u64,
    /// Workers dropped from later rounds.
    pub evictions: u64,
    /// Latest `(‖δᵗ‖², Υᵗ)` when `config: lyapunov` is on.
    pub lyapunov: Option<(f64, f64)>,
    /// Events journaled so far (0 when tracing is off).
    pub trace_events: u64,
}

impl StatusState {
    fn render(&self) -> String {
        let num = |v: u64| Json::Num(v as f64);
        let mut o = BTreeMap::new();
        o.insert("algorithm".into(), Json::Str(self.algorithm.clone()));
        o.insert("rounds_total".into(), num(self.rounds_total));
        o.insert("round".into(), num(self.round));
        o.insert("epoch".into(), num(self.epoch));
        o.insert(
            "live_slots".into(),
            num(self.slots.iter().filter(|s| s.active).count() as u64),
        );
        let slots: Vec<Json> = self
            .slots
            .iter()
            .map(|s| {
                let mut so = BTreeMap::new();
                so.insert("slot".into(), num(s.slot as u64));
                so.insert("active".into(), Json::Bool(s.active));
                so.insert(
                    "rtt_ms".into(),
                    s.rtt_ms.map_or(Json::Null, Json::Num),
                );
                so.insert(
                    "jitter_ms".into(),
                    s.jitter_ms.map_or(Json::Null, Json::Num),
                );
                so.insert("samples".into(), num(s.samples));
                Json::Obj(so)
            })
            .collect();
        o.insert("slots".into(), Json::Arr(slots));
        o.insert(
            "net".into(),
            match self.net {
                None => Json::Null,
                Some(n) => {
                    let mut no = BTreeMap::new();
                    no.insert("wire_uplink".into(), num(n.wire_uplink));
                    no.insert("wire_downlink".into(), num(n.wire_downlink));
                    no.insert("raw_uplink".into(), num(n.raw_uplink));
                    no.insert("raw_downlink".into(), num(n.raw_downlink));
                    Json::Obj(no)
                }
            },
        );
        o.insert("uplink_bytes".into(), num(self.uplink_bytes));
        o.insert(
            "coordinator_ingress_bytes".into(),
            num(self.coordinator_ingress_bytes),
        );
        o.insert(
            "relayed_uplink_bytes".into(),
            num(self.relayed_uplink_bytes),
        );
        o.insert("downlink_bytes".into(), num(self.downlink_bytes));
        o.insert(
            "coordinator_egress_bytes".into(),
            num(self.coordinator_egress_bytes),
        );
        o.insert(
            "relayed_downlink_bytes".into(),
            num(self.relayed_downlink_bytes),
        );
        o.insert("relay_resyncs".into(), num(self.relay_resyncs));
        o.insert("evictions".into(), num(self.evictions));
        o.insert(
            "lyapunov".into(),
            match self.lyapunov {
                None => Json::Null,
                Some((dev, drift)) => {
                    let mut lo = BTreeMap::new();
                    lo.insert("deviation_sq".into(), Json::Num(dev));
                    lo.insert("drift".into(), Json::Num(drift));
                    Json::Obj(lo)
                }
            },
        );
        o.insert("trace_events".into(), num(self.trace_events));
        Json::Obj(o).to_string()
    }
}

/// Shared cell between the trainer (writer) and the listener thread
/// (reader). Cloning shares the same state.
#[derive(Clone)]
pub struct StatusHandle {
    state: Arc<Mutex<StatusState>>,
}

impl StatusHandle {
    /// Overwrite fields under the lock (the trainer's per-round push).
    pub fn update<F: FnOnce(&mut StatusState)>(&self, f: F) {
        let mut s = self.state.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut s);
    }

    /// Render the current snapshot (what a connection receives).
    pub fn render(&self) -> String {
        self.state
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .render()
    }
}

/// The bound endpoint: listener thread + shared state. Dropping it
/// stops the thread and closes the listener.
pub struct StatusServer {
    addr: SocketAddr,
    handle: StatusHandle,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatusServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7900"`; port 0 picks one) and
    /// start serving snapshots.
    pub fn bind(addr: &str) -> std::io::Result<StatusServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let handle = StatusHandle {
            state: Arc::new(Mutex::new(StatusState::default())),
        };
        let stop = Arc::new(AtomicBool::new(false));
        let mut poller = Poller::new()?;
        poller.register(listener.as_raw_fd(), 0)?;
        let thread = {
            let handle = handle.clone();
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("rosdhb-status".into())
                .spawn(move || {
                    let mut ready = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let _ = poller
                            .wait(Duration::from_millis(200), &mut ready);
                        if ready.is_empty() {
                            continue;
                        }
                        loop {
                            match listener.accept() {
                                Ok((stream, _)) => serve_one(stream, &handle),
                                Err(e)
                                    if e.kind()
                                        == std::io::ErrorKind::WouldBlock =>
                                {
                                    break
                                }
                                Err(_) => break,
                            }
                        }
                    }
                })?
        };
        Ok(StatusServer {
            addr: local,
            handle,
            stop,
            thread: Some(thread),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn handle(&self) -> StatusHandle {
        self.handle.clone()
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Answer one connection: swallow whatever request arrived (up to the
/// header terminator or a short timeout — readiness only ever hints)
/// and write one snapshot as a minimal HTTP response.
fn serve_one(mut stream: TcpStream, handle: &StatusHandle) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut buf = [0u8; 1024];
    let mut seen: Vec<u8> = Vec::new();
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                seen.extend_from_slice(&buf[..n]);
                if seen.windows(4).any(|w| w == b"\r\n\r\n")
                    || seen.len() > 8192
                {
                    break;
                }
            }
            Err(_) => break, // timeout or reset — serve the snapshot anyway
        }
    }
    let body = handle.render();
    let response = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Raw HTTP GET against the endpoint, returning the body.
    fn http_get(addr: SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET / HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        let (head, body) = out
            .split_once("\r\n\r\n")
            .expect("response must carry a header/body split");
        assert!(head.starts_with("HTTP/1.1 200"), "head: {head}");
        body.to_string()
    }

    #[test]
    fn status_server_serves_one_snapshot_per_connection() {
        let srv = StatusServer::bind("127.0.0.1:0").unwrap();
        srv.handle().update(|s| {
            s.algorithm = "rosdhb".into();
            s.round = 3;
            s.epoch = 1;
            s.rounds_total = 8;
            s.slots = vec![
                SlotHealth {
                    slot: 0,
                    active: true,
                    rtt_ms: Some(1.25),
                    jitter_ms: Some(0.5),
                    samples: 3,
                },
                SlotHealth {
                    slot: 1,
                    active: false,
                    rtt_ms: None,
                    jitter_ms: None,
                    samples: 0,
                },
            ];
            s.uplink_bytes = 100;
            s.lyapunov = Some((2.0, 0.25));
        });
        let body = http_get(srv.local_addr());
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("round").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("epoch").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("live_slots").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("uplink_bytes").and_then(Json::as_f64), Some(100.0));
        let lyap = j.get("lyapunov").unwrap();
        assert_eq!(
            lyap.get("deviation_sq").and_then(Json::as_f64),
            Some(2.0)
        );
        // a second connection sees the *updated* state
        srv.handle().update(|s| s.round = 4);
        let j2 = Json::parse(&http_get(srv.local_addr())).unwrap();
        assert_eq!(j2.get("round").and_then(Json::as_f64), Some(4.0));
    }
}
