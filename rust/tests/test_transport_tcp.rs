//! Loopback integration tests for the socket runtime: a coordinator and
//! worker threads speaking real TCP over 127.0.0.1 must reproduce the
//! in-process transport's `RunReport` bit for bit, and the bytes measured
//! on the sockets must equal the simulation's `ByteMeter` accounting.

use rosdhb::config::ExperimentConfig;
use rosdhb::coordinator::round_transport::TcpTransport;
use rosdhb::coordinator::{RunReport, Trainer};
use rosdhb::model::MlpSpec;
use rosdhb::transport::net::{CoordinatorServer, NetStats};
use rosdhb::worker::remote::{join_run, JoinOpts, JoinSummary};
use std::thread;
use std::time::Duration;

fn base_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default_mnist_like();
    c.n_honest = 4;
    c.n_byz = 0;
    c.attack = "none".into();
    c.aggregator = "cwtm".into();
    c.k_frac = 0.1;
    c.rounds = 5;
    c.eval_every = 2;
    c.batch = 30;
    c.train_size = 600;
    c.test_size = 200;
    c.stop_at_tau = false;
    c.seed = 7;
    c.transport = "tcp".into();
    c.round_timeout_ms = 20_000;
    c
}

/// Run `cfg` over loopback TCP: one coordinator on this thread, one
/// worker thread per entry of `worker_caps` (a cap injects a mid-run
/// crash after that many rounds). Returns the report, the measured
/// socket traffic, and each worker's outcome.
fn run_tcp(
    cfg: &ExperimentConfig,
    worker_caps: &[Option<u64>],
) -> (RunReport, NetStats, Vec<anyhow::Result<JoinSummary>>) {
    assert_eq!(worker_caps.len(), cfg.n_total());
    let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handles: Vec<_> = worker_caps
        .iter()
        .map(|cap| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            let cap = *cap;
            thread::spawn(move || {
                join_run(
                    &cfg,
                    &addr,
                    Duration::from_secs(20),
                    JoinOpts {
                        max_rounds: cap,
                        ..Default::default()
                    },
                )
            })
        })
        .collect();
    let d = MlpSpec::default().p();
    let transport = TcpTransport::rendezvous(server, cfg, d).unwrap();
    let mut trainer = Trainer::with_transport(cfg, Box::new(transport)).unwrap();
    let report = trainer.run().unwrap();
    let stats = trainer.net_stats().unwrap();
    trainer.shutdown_transport(); // BYE — releases the worker threads
    let outcomes = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (report, stats, outcomes)
}

fn run_local(cfg: &ExperimentConfig) -> RunReport {
    let mut local = cfg.clone();
    local.transport = "local".into();
    Trainer::from_config(&local).unwrap().run().unwrap()
}

/// Every field that must match for "bit-identical RunReport".
fn assert_reports_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.algorithm, b.algorithm);
    assert_eq!(a.rounds_run, b.rounds_run);
    assert_eq!(a.rounds_to_tau, b.rounds_to_tau);
    assert_eq!(a.uplink_bytes_to_tau, b.uplink_bytes_to_tau);
    assert_eq!(a.uplink_bytes, b.uplink_bytes);
    assert_eq!(a.downlink_bytes, b.downlink_bytes);
    assert_eq!(a.coordinator_egress_bytes, b.coordinator_egress_bytes);
    assert_eq!(a.best_acc, b.best_acc);
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(a.log.rows.len(), b.log.rows.len());
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.update_norm, rb.update_norm, "round {}", ra.round);
        assert_eq!(ra.test_acc, rb.test_acc, "round {}", ra.round);
        assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "round {}", ra.round);
        assert_eq!(ra.downlink_bytes, rb.downlink_bytes, "round {}", ra.round);
    }
}

#[test]
fn tcp_rosdhb_run_is_bit_identical_to_local_and_bytes_match_meter() {
    let cfg = base_cfg();
    let (report, stats, outcomes) = run_tcp(&cfg, &[None, None, None, None]);
    for o in &outcomes {
        let s = o.as_ref().expect("worker must finish cleanly");
        assert_eq!(s.rounds, cfg.rounds as u64);
        assert_eq!(s.role, "honest");
    }

    // 1) same seed, same config, two transports — identical report
    let local = run_local(&cfg);
    assert_reports_identical(&report, &local);

    // 2) the bytes that actually crossed the sockets equal the
    //    simulation's accounting model, direction by direction
    assert_eq!(stats.wire_uplink, report.uplink_bytes, "uplink");
    assert_eq!(stats.wire_downlink, report.downlink_bytes, "downlink");
    // raw socket traffic adds only the framing envelopes
    assert!(stats.raw_uplink > stats.wire_uplink);
    assert!(stats.raw_downlink > stats.wire_downlink);
}

#[test]
fn tcp_payload_attack_drones_keep_parity() {
    // 4 honest gradient workers + 1 Byzantine drone: ALIE is crafted
    // server-side, but the drone's placeholder uplink and its broadcast
    // copy keep measured traffic equal to the model.
    let mut cfg = base_cfg();
    cfg.n_byz = 1;
    cfg.attack = "alie".into();
    cfg.rounds = 3;
    let (report, stats, outcomes) = run_tcp(&cfg, &[None; 5]);
    let mut roles: Vec<&str> = outcomes
        .iter()
        .map(|o| o.as_ref().unwrap().role)
        .collect();
    roles.sort_unstable();
    assert_eq!(roles, ["drone", "honest", "honest", "honest", "honest"]);

    let local = run_local(&cfg);
    assert_reports_identical(&report, &local);
    assert_eq!(stats.wire_uplink, report.uplink_bytes);
    assert_eq!(stats.wire_downlink, report.downlink_bytes);
}

#[test]
fn tcp_dense_baseline_full_gradients_keep_parity() {
    // robust-dgd ships dense payloads — the trivial wire plan.
    let mut cfg = base_cfg();
    cfg.set("algorithm", "robust-dgd").unwrap();
    cfg.rounds = 2;
    let (report, stats, outcomes) = run_tcp(&cfg, &[None; 4]);
    for o in &outcomes {
        assert!(o.is_ok());
    }
    let local = run_local(&cfg);
    assert_reports_identical(&report, &local);
    assert_eq!(stats.wire_uplink, report.uplink_bytes);
    assert_eq!(stats.wire_downlink, report.downlink_bytes);
}

/// Shared body of the per-wire-plan parity tests: run `cfg` over loopback
/// TCP and locally, demand a bit-identical `RunReport` and measured
/// socket bytes equal to the `ByteMeter` model.
fn assert_plan_parity(cfg: &ExperimentConfig) {
    let (report, stats, outcomes) = run_tcp(cfg, &vec![None; cfg.n_total()]);
    for o in &outcomes {
        let s = o.as_ref().expect("worker must finish cleanly");
        assert_eq!(s.rounds, cfg.rounds as u64);
    }
    let local = run_local(cfg);
    assert_reports_identical(&report, &local);
    assert_eq!(stats.wire_uplink, report.uplink_bytes, "uplink");
    assert_eq!(stats.wire_downlink, report.downlink_bytes, "downlink");
}

#[test]
fn tcp_rosdhb_local_worker_drawn_masks_keep_parity() {
    // rosdhb-local: every worker draws its own mask client-side
    // (CompressorState) and ships it as a MaskWire — the SparseLocal
    // wire plan the transport used to reject.
    let mut cfg = base_cfg();
    cfg.set("algorithm", "rosdhb-local").unwrap();
    cfg.rounds = 3;
    assert_plan_parity(&cfg);
}

#[test]
fn tcp_rosdhb_u_randk_keeps_parity_with_poisoned_workers() {
    // rosdhb-u with the RandK backend under a data-level attack: the
    // Byzantine slots are real worker processes computing on poisoned
    // shards, compressing through the same client-side state.
    let mut cfg = base_cfg();
    cfg.set("algorithm", "rosdhb-u").unwrap();
    cfg.set("compressor", "randk").unwrap();
    cfg.n_byz = 1;
    cfg.attack = "labelflip".into();
    cfg.aggregator = "nnm+cwtm".into();
    cfg.rounds = 3;
    assert_plan_parity(&cfg);
}

#[test]
fn tcp_rosdhb_u_qsgd_quantized_payloads_keep_parity() {
    // rosdhb-u with QSGD: bit-packed QuantBlock uplinks whose measured
    // socket bytes must equal the packed-width byte model (not 4·k).
    let mut cfg = base_cfg();
    cfg.set("algorithm", "rosdhb-u").unwrap();
    cfg.set("compressor", "qsgd:4").unwrap();
    cfg.rounds = 3;
    assert_plan_parity(&cfg);
}

#[test]
fn tcp_dasha_difference_payloads_keep_parity() {
    // byz-dasha-page: a dense init uplink in round 1, then masked
    // difference payloads; every worker tracks its own gradient-estimate
    // copy client-side, advanced by the same `dasha_apply` law as the
    // coordinator's — three rounds cover both uplink shapes.
    let mut cfg = base_cfg();
    cfg.set("algorithm", "byz-dasha-page").unwrap();
    cfg.rounds = 3;
    assert_plan_parity(&cfg);
}

#[test]
fn tcp_dasha_worker_crash_is_evicted_and_run_completes() {
    // DASHA is stateful on the client (gradient-estimate copy), so a
    // dropped contribution substitutes a size-true zero payload AND
    // evicts the worker — its frozen server-side estimate row must not
    // receive further (diverged) differences. The run keeps completing.
    let mut cfg = base_cfg();
    cfg.set("algorithm", "byz-dasha-page").unwrap();
    cfg.rounds = 4;
    cfg.round_timeout_ms = 60_000;
    let (report, _stats, outcomes) =
        run_tcp(&cfg, &[None, None, Some(2), None]);
    assert_eq!(outcomes[2].as_ref().unwrap().rounds, 2);
    assert_eq!(report.rounds_run, 4);
    for row in &report.log.rows {
        assert!(row.train_loss.is_finite(), "round {}", row.round);
    }
    // parity with the all-workers run holds up to the crash round only
    let full = run_local(&cfg);
    assert_eq!(report.log.rows[0].train_loss, full.log.rows[0].train_loss);
    assert_ne!(report.log.rows[3].train_loss, full.log.rows[3].train_loss);
}

#[test]
fn tcp_dgd_randk_keeps_parity() {
    // dgd-randk: worker-drawn masks, plain averaging, no momentum.
    let mut cfg = base_cfg();
    cfg.set("algorithm", "dgd-randk").unwrap();
    cfg.rounds = 2;
    assert_plan_parity(&cfg);
}

#[test]
fn tcp_epoch_churn_leave_and_join_matches_local_oracle() {
    // Elastic membership: slot 1 is churned out at the boundary opening
    // epoch 2 (round 5) and a replacement — dialing since the run
    // started, parked in the listener backlog — is admitted into the
    // vacated slot when the epoch-3 boundary (round 7) re-opens
    // rendezvous. The local oracle under the identical schedule (a
    // vacant slot contributes an exact zero) must produce a bit-identical
    // RunReport, with the incremental-geometry rebuild counters pinned
    // across the membership change.
    let mut cfg = base_cfg();
    cfg.aggregator = "nnm+cwtm".into();
    cfg.rounds = 8;
    cfg.set("epoch_rounds", "2").unwrap();
    cfg.set("churn", "2:-1,3:+1").unwrap();

    let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let initial: Vec<_> = (0..cfg.n_total())
        .map(|_| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || {
                join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
            })
        })
        .collect();
    let d = MlpSpec::default().p();
    let transport = TcpTransport::rendezvous(server, &cfg, d).unwrap();
    // dial the replacement only after every initial slot is filled: its
    // connection waits in the backlog until the boundary window opens
    let replacement = {
        let cfg = cfg.clone();
        let addr = addr.clone();
        thread::spawn(move || {
            join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
        })
    };
    let mut trainer = Trainer::with_transport(&cfg, Box::new(transport)).unwrap();
    let report = trainer.run().unwrap();
    let tcp_geo = trainer.geometry_stats();
    trainer.shutdown_transport();

    let mut outcomes: Vec<JoinSummary> = initial
        .into_iter()
        .map(|h| h.join().unwrap().expect("initial worker must finish"))
        .collect();
    outcomes.push(
        replacement
            .join()
            .unwrap()
            .expect("replacement must finish"),
    );
    let repl = outcomes.last().unwrap();
    assert_eq!(repl.worker_id, 1, "replacement re-fills the vacated slot");
    assert_eq!(repl.role, "honest");
    // churned-out worker: rounds 1-4; replacement: rounds 7-8; the
    // other three serve the whole run
    let mut served: Vec<u64> = outcomes.iter().map(|s| s.rounds).collect();
    served.sort_unstable();
    assert_eq!(served, [2, 4, 8, 8, 8]);

    // determinism never depends on join order: the local oracle under
    // the same churn schedule reproduces the socket run bit for bit
    // (wire bytes measured on the sockets are *below* the meter model
    // while the slot sits vacant, so only the report is compared)
    let mut local_cfg = cfg.clone();
    local_cfg.transport = "local".into();
    let mut local = Trainer::from_config(&local_cfg).unwrap();
    let local_report = local.run().unwrap();
    assert_reports_identical(&report, &local_report);
    assert_eq!(
        tcp_geo,
        local.geometry_stats(),
        "geometry rebuild counters must be pinned across the churn"
    );
}

#[test]
fn tcp_checkpoint_restore_resumes_bit_identically() {
    // The E = 2 acceptance criterion over real sockets: 2E epochs
    // straight must equal E epochs → checkpoint → a brand-new
    // coordinator with fresh worker connections restoring → E more
    // epochs. Delta downlink exercises the codec across the boundary
    // (counters ride the checkpoint; the carry basis is re-seeded by the
    // boundary's dense re-sync) and nnm+cwtm pins the geometry counters.
    let mut cfg = base_cfg();
    cfg.aggregator = "nnm+cwtm".into();
    cfg.downlink = "delta".into();
    cfg.rounds = 8;
    cfg.set("epoch_rounds", "2").unwrap();

    let (straight, straight_stats, _) = run_tcp(&cfg, &[None; 4]);

    let ckpt = std::env::temp_dir().join(format!(
        "rosdhb_tcp_restore_{}.ckpt",
        std::process::id()
    ));

    // epochs 0-1, checkpointing at every boundary: the round-4 write is
    // the one the restore picks up
    let mut first = cfg.clone();
    first.rounds = 4;
    {
        let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let handles: Vec<_> = (0..first.n_total())
            .map(|_| {
                let cfg = first.clone();
                let addr = addr.clone();
                thread::spawn(move || {
                    join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
                })
            })
            .collect();
        let d = MlpSpec::default().p();
        let transport = TcpTransport::rendezvous(server, &first, d).unwrap();
        let mut trainer =
            Trainer::with_transport(&first, Box::new(transport)).unwrap();
        trainer.set_checkpoint(&ckpt, 1);
        trainer.run().unwrap();
        trainer.shutdown_transport();
        for h in handles {
            assert_eq!(h.join().unwrap().unwrap().rounds, 4);
        }
    }

    // a new process would do exactly this: fresh sockets, fresh workers,
    // restore, run epochs 2-3
    let restored = {
        let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let handles: Vec<_> = (0..cfg.n_total())
            .map(|_| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                thread::spawn(move || {
                    join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
                })
            })
            .collect();
        let d = MlpSpec::default().p();
        let transport = TcpTransport::rendezvous(server, &cfg, d).unwrap();
        let mut trainer =
            Trainer::with_transport(&cfg, Box::new(transport)).unwrap();
        trainer.load_checkpoint(&ckpt).unwrap();
        let report = trainer.run().unwrap();
        let stats = trainer.net_stats().unwrap();
        trainer.shutdown_transport();
        for h in handles {
            // the resumed run serves only rounds 5-8
            assert_eq!(h.join().unwrap().unwrap().rounds, 4);
        }
        (report, stats)
    };
    std::fs::remove_file(&ckpt).ok();

    assert_reports_identical(&straight, &restored.0);
    // measured wire traffic is cumulative across the restore (preseeded
    // from the checkpoint); raw bytes differ by the second handshake
    assert_eq!(restored.1.wire_uplink, straight_stats.wire_uplink);
    assert_eq!(restored.1.wire_downlink, straight_stats.wire_downlink);
}

#[test]
fn tcp_churn_then_restore_keeps_vacated_slot_vacant() {
    // Regression: a checkpoint written *after* a churn event carries the
    // membership, and a restoring coordinator rendezvouses only the
    // active slots. Slot 1 is churned out at the epoch-1 boundary
    // (round 3); the checkpoint lands at round 4 with the slot vacant;
    // the restored run brings up THREE workers (a fourth would block
    // rendezvous forever — the old full-rendezvous restore both hung on
    // it and silently re-activated the slot), then a replacement joins
    // through the epoch-3 boundary window (round 7) exactly as on the
    // straight run.
    let mut cfg = base_cfg();
    cfg.aggregator = "nnm+cwtm".into();
    cfg.rounds = 8;
    cfg.set("epoch_rounds", "2").unwrap();
    cfg.set("churn", "1:-1,3:+1").unwrap();

    // --- straight run: 4 initial workers + a replacement in the backlog
    let straight = {
        let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let initial: Vec<_> = (0..cfg.n_total())
            .map(|_| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                thread::spawn(move || {
                    join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
                })
            })
            .collect();
        let d = MlpSpec::default().p();
        let transport = TcpTransport::rendezvous(server, &cfg, d).unwrap();
        let replacement = {
            let cfg = cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || {
                join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
            })
        };
        let mut trainer =
            Trainer::with_transport(&cfg, Box::new(transport)).unwrap();
        let report = trainer.run().unwrap();
        let geo = trainer.geometry_stats();
        trainer.shutdown_transport();
        let mut served: Vec<u64> = initial
            .into_iter()
            .map(|h| h.join().unwrap().expect("initial worker").rounds)
            .collect();
        served.push(replacement.join().unwrap().expect("replacement").rounds);
        served.sort_unstable();
        assert_eq!(served, [2, 2, 8, 8, 8]);
        (report, geo)
    };

    let ckpt = std::env::temp_dir().join(format!(
        "rosdhb_tcp_churn_restore_{}.ckpt",
        std::process::id()
    ));

    // --- epochs 0-1 with the same schedule; the round-4 checkpoint
    // records slot 1 vacant
    let mut first = cfg.clone();
    first.rounds = 4;
    {
        let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let handles: Vec<_> = (0..first.n_total())
            .map(|_| {
                let cfg = first.clone();
                let addr = addr.clone();
                thread::spawn(move || {
                    join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
                })
            })
            .collect();
        let d = MlpSpec::default().p();
        let transport = TcpTransport::rendezvous(server, &first, d).unwrap();
        let mut trainer =
            Trainer::with_transport(&first, Box::new(transport)).unwrap();
        trainer.set_checkpoint(&ckpt, 1);
        trainer.run().unwrap();
        trainer.shutdown_transport();
        let mut served: Vec<u64> = handles
            .into_iter()
            .map(|h| h.join().unwrap().expect("worker").rounds)
            .collect();
        served.sort_unstable();
        assert_eq!(served, [2, 4, 4, 4]);
    }

    // --- restore: membership-aware rendezvous waits for 3 workers only
    let ck = rosdhb::checkpoint::Checkpoint::read(
        &ckpt,
        cfg.wire_fingerprint(),
    )
    .unwrap();
    let vacant: Vec<usize> = ck
        .membership
        .iter()
        .enumerate()
        .filter(|(_, s)| !s.active)
        .map(|(w, _)| w)
        .collect();
    assert_eq!(vacant, [1], "round-4 checkpoint must record slot 1 vacant");
    let restored = {
        let server = CoordinatorServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let handles: Vec<_> = (0..cfg.n_total() - 1)
            .map(|_| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                thread::spawn(move || {
                    join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
                })
            })
            .collect();
        let d = MlpSpec::default().p();
        let transport =
            TcpTransport::rendezvous_restored(server, &cfg, d, &ck.membership)
                .unwrap();
        let replacement = {
            let cfg = cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || {
                join_run(&cfg, &addr, Duration::from_secs(20), JoinOpts::default())
            })
        };
        let mut trainer =
            Trainer::with_transport(&cfg, Box::new(transport)).unwrap();
        trainer.load_checkpoint(&ckpt).unwrap();
        let report = trainer.run().unwrap();
        let geo = trainer.geometry_stats();
        trainer.shutdown_transport();
        let mut served: Vec<u64> = handles
            .into_iter()
            .map(|h| h.join().unwrap().expect("restored worker").rounds)
            .collect();
        served.push(replacement.join().unwrap().expect("replacement").rounds);
        served.sort_unstable();
        // three restored workers serve rounds 5-8, the replacement 7-8
        assert_eq!(served, [2, 4, 4, 4]);
        (report, geo)
    };
    std::fs::remove_file(&ckpt).ok();

    assert_reports_identical(&straight.0, &restored.0);
    assert_eq!(
        straight.1, restored.1,
        "geometry rebuild counters must be pinned across the restore"
    );

    // the local oracle under the identical schedule agrees bit for bit
    let mut local_cfg = cfg.clone();
    local_cfg.transport = "local".into();
    let local = Trainer::from_config(&local_cfg).unwrap().run().unwrap();
    assert_reports_identical(&restored.0, &local);
}

#[test]
fn tcp_worker_crash_mid_run_degrades_into_dropped_contribution() {
    let mut cfg = base_cfg();
    cfg.rounds = 4;
    // a dead socket is detected by the I/O thread, not by the round
    // deadline, so a long timeout must not slow the surviving rounds
    cfg.round_timeout_ms = 60_000;
    let (report, _stats, outcomes) =
        run_tcp(&cfg, &[None, None, None, Some(2)]);
    // the crashed worker served exactly 2 rounds and dropped out cleanly
    assert_eq!(outcomes[3].as_ref().unwrap().rounds, 2);
    // the run still completed every round with finite losses
    assert_eq!(report.rounds_run, 4);
    for row in &report.log.rows {
        assert!(row.train_loss.is_finite(), "round {}", row.round);
    }
    // and it diverged from the all-workers run only after the crash
    let full = run_local(&cfg);
    assert_eq!(
        report.log.rows[0].train_loss,
        full.log.rows[0].train_loss
    );
    assert_ne!(
        report.log.rows[3].train_loss,
        full.log.rows[3].train_loss
    );
}
