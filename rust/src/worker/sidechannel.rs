//! The worker's observation side channel to the coordinator's status
//! listener — clock-alignment probes (`GET /clock`) and per-worker stat
//! pushes (`POST /worker`).
//!
//! **Side channel, never the data path.** Everything here talks to the
//! status listener (`config: status_addr`) over its own short-lived
//! connections; the gradient/broadcast sockets are never touched, so
//! the tracing-invariance oracle (data-socket bytes bit-identical with
//! observation on or off) holds by construction. Every call is
//! best-effort with short timeouts: a dead or slow listener turns the
//! channel off for the rest of the run, it never fails a round.

use crate::telemetry::{Histogram, Telemetry};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Per-connection budget — the side channel must never hold a round
/// hostage even when the listener is wedged.
const SIDE_TIMEOUT: Duration = Duration::from_millis(750);

/// Clock-probe samples per alignment; the minimum-RTT sample wins
/// (NTP's classic filter: the tightest round trip bounds the offset
/// error by rtt/2).
const CLOCK_SAMPLES: usize = 3;

/// Worker-local round-phase histograms shipped upstream: time blocked
/// on the downlink (`wait`), gradient + compress time (`compute`), and
/// uplink write time (`reply`).
#[derive(Clone, Debug, Default)]
pub struct WorkerPhases {
    pub wait: Histogram,
    pub compute: Histogram,
    pub reply: Histogram,
    pub rounds: u64,
}

impl WorkerPhases {
    /// JSON summary for the `POST /worker` body (p50/p99 per phase).
    fn to_json(&self) -> Json {
        let hist = |h: &Histogram| {
            let mut m = BTreeMap::new();
            m.insert(
                "p50_us".into(),
                Json::Num(h.quantile_floor_us(0.5) as f64),
            );
            m.insert(
                "p99_us".into(),
                Json::Num(h.quantile_floor_us(0.99) as f64),
            );
            Json::Obj(m)
        };
        let mut m = BTreeMap::new();
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("wait".into(), hist(&self.wait));
        m.insert("compute".into(), hist(&self.compute));
        m.insert("reply".into(), hist(&self.reply));
        Json::Obj(m)
    }
}

/// One blocking HTTP exchange with the status listener; returns the
/// response body of a 200, `None` on any failure — callers treat that
/// as "turn the channel off", never as a round error.
fn status_http(addr: &str, request: &str) -> Option<String> {
    let sa = addr.to_socket_addrs().ok()?.next()?;
    let mut s = TcpStream::connect_timeout(&sa, SIDE_TIMEOUT).ok()?;
    s.set_read_timeout(Some(SIDE_TIMEOUT)).ok()?;
    s.set_write_timeout(Some(SIDE_TIMEOUT)).ok()?;
    s.write_all(request.as_bytes()).ok()?;
    let _ = s.shutdown(Shutdown::Write);
    let mut buf = String::new();
    s.read_to_string(&mut buf).ok()?;
    let (head, body) = buf.split_once("\r\n\r\n")?;
    if !head.starts_with("HTTP/1.1 200") {
        return None;
    }
    Some(body.to_string())
}

/// NTP-style alignment against `GET /clock`: the offset such that
/// `tel.local_now_us() + offset ≈ coordinator clock`. Returns
/// `(offset_us, rtt_us)` of the minimum-RTT sample, or `None` when the
/// listener never answered.
pub fn probe_clock(addr: &str, tel: &Telemetry) -> Option<(i64, u64)> {
    let mut best: Option<(i64, u64)> = None;
    for _ in 0..CLOCK_SAMPLES {
        let t1 = tel.local_now_us() as i64;
        let Some(body) = status_http(addr, "GET /clock HTTP/1.1\r\n\r\n")
        else {
            continue;
        };
        let t2 = tel.local_now_us() as i64;
        let Some(ts) = Json::parse(body.trim())
            .ok()
            .and_then(|j| j.get("ts_us").and_then(Json::as_f64))
        else {
            continue;
        };
        let rtt = (t2 - t1).max(0) as u64;
        let offset = ts as i64 - (t1 + rtt as i64 / 2);
        let better = match best {
            None => true,
            Some((_, r)) => rtt < r,
        };
        if better {
            best = Some((offset, rtt));
        }
    }
    best
}

/// Ship one worker-stat update over the side channel. Returns `false`
/// when the push failed (callers go sticky-off).
pub fn push_stats(
    addr: &str,
    worker: u16,
    round: u64,
    clock: Option<(i64, u64)>,
    phases: &WorkerPhases,
    resyncs: u32,
    gap: Option<(bool, u64)>,
) -> bool {
    let mut m = BTreeMap::new();
    m.insert("worker".into(), Json::Num(worker as f64));
    m.insert("round".into(), Json::Num(round as f64));
    m.insert(
        "offset_us".into(),
        clock.map_or(Json::Null, |(o, _)| Json::Num(o as f64)),
    );
    m.insert(
        "rtt_us".into(),
        clock.map_or(Json::Null, |(_, r)| Json::Num(r as f64)),
    );
    m.insert("resyncs".into(), Json::Num(resyncs as f64));
    m.insert(
        "gap".into(),
        gap.map_or(Json::Null, |(armed, threshold_us)| {
            let mut g = BTreeMap::new();
            g.insert("armed".into(), Json::Bool(armed));
            g.insert("threshold_us".into(), Json::Num(threshold_us as f64));
            Json::Obj(g)
        }),
    );
    m.insert("phases".into(), phases.to_json());
    let body = Json::Obj(m).to_string();
    let req = format!(
        "POST /worker HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    status_http(addr, &req).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::status::StatusServer;
    use std::sync::Arc;

    #[test]
    fn probe_aligns_to_the_served_clock_and_push_lands() {
        let srv = StatusServer::bind("127.0.0.1:0").unwrap();
        srv.handle().set_clock_source(Arc::new(|| 5_000_000));
        let tel = Telemetry::disabled();
        let addr = srv.local_addr().to_string();
        let (offset, _rtt) = probe_clock(&addr, &tel).unwrap();
        // a disabled journal's local clock reads 0, so the offset is the
        // served timestamp itself
        assert_eq!(offset, 5_000_000);
        let mut phases = WorkerPhases {
            rounds: 1,
            ..Default::default()
        };
        phases.wait.record_us(120);
        assert!(push_stats(
            &addr,
            3,
            7,
            Some((offset, 0)),
            &phases,
            0,
            Some((true, 250_000)),
        ));
        let snap = srv.handle().render();
        assert!(snap.contains("\"offset_us\":5000000"), "{snap}");
        assert!(snap.contains("\"threshold_us\":250000"), "{snap}");
    }

    #[test]
    fn dead_listener_is_a_clean_none_not_an_error() {
        let tel = Telemetry::disabled();
        // a port nothing listens on: bind-then-drop reserves a dead one
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(probe_clock(&dead, &tel).is_none());
        assert!(!push_stats(
            &dead,
            0,
            1,
            None,
            &WorkerPhases::default(),
            0,
            None,
        ));
    }
}
